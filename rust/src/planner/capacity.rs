//! Capacity planning & routing with fixed traffic (Eq. 23–26):
//!     min_{N, x}  max_t L_t^{(N)}  +  β · Σ_{m,i} c_{m,i} · N_{m,i}
//! s.t. one-assignment, capacity, SLO, stability, N ∈ Z≥1.
//!
//! Bounded exact search: for each candidate routing (from the Eq. 18
//! enumerator's candidate sets) the optimal N per used pool decomposes —
//! g(N) is monotone decreasing in N, so the cost-optimal N for a pool is
//! the smallest stable N meeting the SLO, and the latency/cost frontier is
//! swept by growing N while the marginal max-latency gain beats β·c.

use super::routing::{Placement, TaskClass};
use crate::cluster::DeploymentKey;
use crate::config::Config;
use crate::latency_model::Predictor;

/// Result of capacity planning.
#[derive(Debug, Clone)]
pub struct CapacityPlan {
    /// replicas[m][i] chosen.
    pub replicas: Vec<Vec<u32>>,
    pub placements: Vec<Placement>,
    /// max_t latency at the optimum.
    pub worst_latency: f64,
    /// β·Σ c·N at the optimum.
    pub cost: f64,
    /// Objective value (latency + cost).
    pub objective: f64,
}

/// Solve Eq. 23 for the given task classes.
///
/// `beta` is the cost–latency trade-off (paper: β = 2.5). Latency
/// evaluations go through a private prediction plane built from `cfg` —
/// the frozen closed form unless `prediction.online` has re-fits.
pub fn plan_capacity(cfg: &Config, classes: &[TaskClass], beta: f64) -> Option<CapacityPlan> {
    plan_capacity_with(cfg, classes, beta, &Predictor::from_config(cfg))
}

/// [`plan_capacity`] over a *shared* prediction plane: re-planning with
/// drift-recalibrated laws (e.g. after a fail-slow window) sees the
/// effective — not nominal — per-pool capacity.
pub fn plan_capacity_with(
    cfg: &Config,
    classes: &[TaskClass],
    beta: f64,
    predictor: &Predictor,
) -> Option<CapacityPlan> {
    if classes.is_empty() {
        return Some(CapacityPlan {
            replicas: vec![vec![0; cfg.instances.len()]; cfg.models.len()],
            placements: Vec::new(),
            worst_latency: 0.0,
            cost: 0.0,
            objective: 0.0,
        });
    }

    // Candidate pools per class: accuracy-feasible (m, i).
    let mut candidates: Vec<Vec<(usize, usize)>> = Vec::new();
    for class in classes {
        let mut cands = Vec::new();
        for (m, model) in cfg.models.iter().enumerate() {
            if model.accuracy + 1e-12 < class.min_accuracy {
                continue;
            }
            for i in 0..cfg.instances.len() {
                cands.push((m, i));
            }
        }
        if cands.is_empty() {
            return None;
        }
        candidates.push(cands);
    }

    let mut best: Option<CapacityPlan> = None;
    let mut idx = vec![0usize; classes.len()];
    'outer: loop {
        // Aggregate λ per pool under this routing.
        let mut lambda_mi = vec![vec![0.0; cfg.instances.len()]; cfg.models.len()];
        for (c, &k) in idx.iter().enumerate() {
            let (m, i) = candidates[c][k];
            lambda_mi[m][i] += classes[c].lambda;
        }

        // Per-pool: sweep N from the minimal stable+SLO count upward while
        // the objective improves (g monotone ⇒ the sweep is the frontier).
        let mut replicas = vec![vec![0u32; cfg.instances.len()]; cfg.models.len()];
        let mut feasible = true;
        let mut cost = 0.0;
        'pools: for m in 0..cfg.models.len() {
            for i in 0..cfg.instances.len() {
                let lam = lambda_mi[m][i];
                if lam <= 0.0 {
                    continue;
                }
                let pool = DeploymentKey { model: m, instance: i };
                let n_max = cfg.instances[i].n_max;
                // Tightest SLO among classes routed here.
                let tau = idx
                    .iter()
                    .enumerate()
                    .filter(|(c, &k)| candidates[*c][k] == (m, i))
                    .filter_map(|(c, _)| classes[c].slo)
                    .fold(f64::INFINITY, f64::min);
                // Minimal N: stable + SLO.
                let mut n_opt = None;
                for n in 1..=n_max {
                    let g = predictor.g_n(pool, n, lam);
                    if g.is_finite() && g <= tau {
                        n_opt = Some(n);
                        break;
                    }
                }
                let Some(mut n) = n_opt else {
                    feasible = false;
                    break 'pools;
                };
                // Grow N while the latency drop beats the marginal cost.
                while n < n_max {
                    let gain = predictor.g_n(pool, n, lam) - predictor.g_n(pool, n + 1, lam);
                    if gain > beta * cfg.instances[i].cost {
                        n += 1;
                    } else {
                        break;
                    }
                }
                replicas[m][i] = n;
                cost += beta * cfg.instances[i].cost * n as f64;
            }
        }

        if feasible {
            // Capacity check (Eq. 20 analogue at the instance level).
            for i in 0..cfg.instances.len() {
                let demand: f64 = (0..cfg.models.len())
                    .map(|m| lambda_mi[m][i] * cfg.models[m].r_cost)
                    .sum();
                if demand > cfg.instances[i].r_max + 1e-9 {
                    feasible = false;
                }
            }
        }

        if feasible {
            let mut worst = 0.0f64;
            let mut placements = Vec::new();
            for (c, &k) in idx.iter().enumerate() {
                let (m, i) = candidates[c][k];
                let g = predictor.g_n(
                    DeploymentKey { model: m, instance: i },
                    replicas[m][i],
                    lambda_mi[m][i],
                );
                worst = worst.max(g);
                placements.push(Placement {
                    class: c,
                    model: m,
                    instance: i,
                    latency: g,
                });
            }
            let objective = worst + cost;
            if best
                .as_ref()
                .map(|b| objective < b.objective)
                .unwrap_or(true)
            {
                best = Some(CapacityPlan {
                    replicas,
                    placements,
                    worst_latency: worst,
                    cost,
                    objective,
                });
            }
        }

        // Odometer.
        let mut pos = 0;
        loop {
            if pos == classes.len() {
                break 'outer;
            }
            idx[pos] += 1;
            if idx[pos] < candidates[pos].len() {
                break;
            }
            idx[pos] = 0;
            pos += 1;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QualityClass;
    use crate::latency_model::LatencyModel;

    fn class(lambda: f64, slo: f64, acc: f64) -> TaskClass {
        TaskClass {
            name: "c".into(),
            quality: QualityClass::Balanced,
            lambda,
            slo: Some(slo),
            min_accuracy: acc,
        }
    }

    #[test]
    fn plans_minimal_stable_pool() {
        let cfg = Config::default();
        let plan = plan_capacity(&cfg, &[class(2.0, 1.8, 0.5)], 2.5).unwrap();
        // The chosen pool must be stable at λ=2 and meet the SLO.
        assert!(plan.worst_latency <= 1.8);
        assert_eq!(plan.placements.len(), 1);
        let p = plan.placements[0];
        assert!(cfg.models[p.model].accuracy >= 0.5);
        let lm = LatencyModel::from_config(&cfg, p.model, p.instance);
        let n = plan.replicas[p.model][p.instance];
        assert!(n >= 1 && lm.is_stable(2.0, n), "unstable plan n={n}");
    }

    #[test]
    fn replicas_grow_with_load() {
        // The planner may absorb moderate load on a fast pool without new
        // replicas; compare far-apart rates so growth is forced.
        let cfg = Config::default();
        let lo = plan_capacity(&cfg, &[class(1.0, 1.8, 0.5)], 2.5).unwrap();
        let hi = plan_capacity(&cfg, &[class(14.0, 1.8, 0.5)], 2.5).unwrap();
        let sum = |p: &CapacityPlan| p.replicas.iter().flatten().sum::<u32>();
        assert!(sum(&hi) > sum(&lo), "hi={} lo={}", sum(&hi), sum(&lo));
    }

    #[test]
    fn higher_beta_buys_fewer_replicas() {
        let cfg = Config::default();
        let cheap = plan_capacity(&cfg, &[class(3.0, 3.0, 0.5)], 0.01).unwrap();
        let pricey = plan_capacity(&cfg, &[class(3.0, 3.0, 0.5)], 50.0).unwrap();
        let sum = |p: &CapacityPlan| p.replicas.iter().flatten().sum::<u32>();
        assert!(
            sum(&cheap) >= sum(&pricey),
            "cheap={} pricey={}",
            sum(&cheap),
            sum(&pricey)
        );
        // With near-free replicas the worst latency must be at least as good.
        assert!(cheap.worst_latency <= pricey.worst_latency + 1e-9);
    }

    #[test]
    fn impossible_slo_returns_none() {
        let cfg = Config::default();
        assert!(plan_capacity(&cfg, &[class(50.0, 0.05, 0.5)], 2.5).is_none());
    }

    #[test]
    fn empty_classes_zero_plan() {
        let cfg = Config::default();
        let plan = plan_capacity(&cfg, &[], 2.5).unwrap();
        assert_eq!(plan.objective, 0.0);
    }

    #[test]
    fn stability_constraint_eq25_holds() {
        let cfg = Config::default();
        let plan = plan_capacity(&cfg, &[class(4.0, 2.5, 0.5)], 2.5).unwrap();
        for p in &plan.placements {
            let lm = LatencyModel::from_config(&cfg, p.model, p.instance);
            let n = plan.replicas[p.model][p.instance];
            // λ < N·μ (Eq. 25).
            assert!(lm.is_stable(4.0 * 0.999, n));
        }
    }
}
