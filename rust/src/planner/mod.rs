//! Optimisation stages of §III-H:
//!
//! * [`route_tasks`] — workload routing with fixed replica layout
//!   (Eq. 18–22): assign task classes to (model, instance) minimising the
//!   worst task latency under capacity, SLO, and stability constraints.
//! * [`plan_capacity`] — capacity planning & routing with fixed traffic
//!   (Eq. 23–26): jointly size replica pools and choose routing,
//!   minimising max-latency + β·Σ cost·N.
//!
//! The search space is small in the paper's deployments (N ≤ 16, |I| ≤ 4,
//! |M| ≤ 3), so bounded enumeration with Erlang-C feasibility pruning is
//! exact — the closed-form g(N) is the pruning bound (§III-G: marginal
//! benefit flattens once ρ ≲ 0.3).

mod capacity;
mod routing;

pub use capacity::{plan_capacity, plan_capacity_with, CapacityPlan};
pub use routing::{route_tasks, RoutingProblem, TaskClass};
