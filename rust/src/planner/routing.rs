//! Workload routing with a fixed replica layout (Eq. 18):
//!     min_x  max_t  L_t^{(λ)}
//! subject to one-assignment (19), capacity (20), SLO (21), stability (22).
//!
//! Tasks are aggregated into classes (quality lane + rate); assignment is
//! per class. The solver enumerates feasible placements per class in
//! ascending-g order and resolves conflicts by local search — exact for
//! the paper-scale instance counts.

use crate::config::{Config, QualityClass};
use crate::latency_model::LatencyModel;

/// An aggregated stream of tasks with common requirements.
#[derive(Debug, Clone)]
pub struct TaskClass {
    pub name: String,
    pub quality: QualityClass,
    /// Aggregate arrival rate of this class [req/s].
    pub lambda: f64,
    /// Latency SLO τ_t [s]; None = best effort.
    pub slo: Option<f64>,
    /// Minimum accuracy requirement α_t^req.
    pub min_accuracy: f64,
}

/// The routing problem: classes + a fixed replica layout N[m][i].
#[derive(Debug, Clone)]
pub struct RoutingProblem {
    pub classes: Vec<TaskClass>,
    /// replicas[m][i] = N_{m,i} (0 = model m not deployed on i).
    pub replicas: Vec<Vec<u32>>,
}

/// One class's placement in the solution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub class: usize,
    pub model: usize,
    pub instance: usize,
    /// Predicted latency for this class at the chosen pool.
    pub latency: f64,
}

/// Solve Eq. 18 by exhaustive assignment over per-class candidate pools
/// (feasible by accuracy + stability + SLO), minimising the max latency.
/// Returns None when no feasible assignment exists.
pub fn route_tasks(cfg: &Config, problem: &RoutingProblem) -> Option<Vec<Placement>> {
    let n_classes = problem.classes.len();
    if n_classes == 0 {
        return Some(Vec::new());
    }

    // Candidate (m, i) per class, each with its latency model.
    let mut candidates: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n_classes);
    for class in &problem.classes {
        let mut cands = Vec::new();
        for (m, model) in cfg.models.iter().enumerate() {
            if model.accuracy + 1e-12 < class.min_accuracy {
                continue;
            }
            for (i, _) in cfg.instances.iter().enumerate() {
                if problem
                    .replicas
                    .get(m)
                    .and_then(|r| r.get(i))
                    .copied()
                    .unwrap_or(0)
                    > 0
                {
                    cands.push((m, i));
                }
            }
        }
        if cands.is_empty() {
            return None;
        }
        candidates.push(cands);
    }

    // Enumerate assignments (paper scale: |classes| ≤ 3, |cands| ≤ 6 —
    // at most a few hundred combinations).
    let mut best: Option<(f64, Vec<Placement>)> = None;
    let mut idx = vec![0usize; n_classes];
    'outer: loop {
        // Evaluate this assignment: aggregate λ per (m, i) then check.
        let mut lambda_mi = vec![vec![0.0; cfg.instances.len()]; cfg.models.len()];
        for (c, &k) in idx.iter().enumerate() {
            let (m, i) = candidates[c][k];
            lambda_mi[m][i] += problem.classes[c].lambda;
        }

        let mut feasible = true;
        let mut worst = 0.0f64;
        let mut placements = Vec::with_capacity(n_classes);
        // Capacity constraint (20): Σ λ·R ≤ R_max per instance.
        for i in 0..cfg.instances.len() {
            let demand: f64 = (0..cfg.models.len())
                .map(|m| lambda_mi[m][i] * cfg.models[m].r_cost)
                .sum();
            if demand > cfg.instances[i].r_max + 1e-9 {
                feasible = false;
            }
        }
        if feasible {
            for (c, &k) in idx.iter().enumerate() {
                let (m, i) = candidates[c][k];
                let n = problem.replicas[m][i];
                let lm = LatencyModel::from_config(cfg, m, i);
                let g = lm.g_lambda(lambda_mi[m][i], n);
                // Stability (22) + SLO (21).
                if !g.is_finite() {
                    feasible = false;
                    break;
                }
                if let Some(tau) = problem.classes[c].slo {
                    if g > tau {
                        feasible = false;
                        break;
                    }
                }
                worst = worst.max(g);
                placements.push(Placement {
                    class: c,
                    model: m,
                    instance: i,
                    latency: g,
                });
            }
        }
        if feasible && best.as_ref().map(|(w, _)| worst < *w).unwrap_or(true) {
            best = Some((worst, placements));
        }

        // Next assignment (odometer).
        let mut pos = 0;
        loop {
            if pos == n_classes {
                break 'outer;
            }
            idx[pos] += 1;
            if idx[pos] < candidates[pos].len() {
                break;
            }
            idx[pos] = 0;
            pos += 1;
        }
    }
    best.map(|(_, p)| p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout(cfg: &Config, n: u32) -> Vec<Vec<u32>> {
        vec![vec![n; cfg.instances.len()]; cfg.models.len()]
    }

    fn balanced_class(lambda: f64, slo: Option<f64>) -> TaskClass {
        TaskClass {
            name: "robots".into(),
            quality: QualityClass::Balanced,
            lambda,
            slo,
            min_accuracy: 0.5,
        }
    }

    #[test]
    fn single_class_picks_min_latency_pool() {
        let cfg = Config::default();
        let p = RoutingProblem {
            classes: vec![balanced_class(1.0, None)],
            replicas: layout(&cfg, 4),
        };
        let sol = route_tasks(&cfg, &p).unwrap();
        assert_eq!(sol.len(), 1);
        // min_accuracy = 0.5 excludes EfficientDet (0.25): must be a
        // YOLOv5m or R-CNN pool.
        assert!(cfg.models[sol[0].model].accuracy >= 0.5);
        assert!(sol[0].latency.is_finite());
    }

    #[test]
    fn accuracy_constraint_respected() {
        let cfg = Config::default();
        let mut c = balanced_class(1.0, None);
        c.min_accuracy = 0.7; // only faster_rcnn (0.75) qualifies
        let p = RoutingProblem {
            classes: vec![c],
            replicas: layout(&cfg, 4),
        };
        let sol = route_tasks(&cfg, &p).unwrap();
        assert_eq!(cfg.models[sol[0].model].name, "faster_rcnn");
    }

    #[test]
    fn overload_respects_slo_and_stability() {
        let cfg = Config::default();
        // Two heavy classes: a single YOLO edge pool (μ≈1.37·N) cannot hold
        // both within SLO — wherever the solver places them, every class
        // must be stable and within its SLO under the *combined* load.
        let p = RoutingProblem {
            classes: vec![balanced_class(2.0, Some(3.0)), balanced_class(2.0, Some(3.0))],
            replicas: layout(&cfg, 3),
        };
        let sol = route_tasks(&cfg, &p).unwrap();
        for pl in &sol {
            assert!(pl.latency.is_finite() && pl.latency <= 3.0, "{pl:?}");
        }
        // If both landed on one pool, that pool must hold λ=4 stably at N=3.
        if sol[0].model == sol[1].model && sol[0].instance == sol[1].instance {
            let lm = LatencyModel::from_config(&cfg, sol[0].model, sol[0].instance);
            assert!(lm.is_stable(4.0, 3));
        }
    }

    #[test]
    fn infeasible_returns_none() {
        let cfg = Config::default();
        let mut c = balanced_class(100.0, Some(0.1)); // impossible SLO
        c.min_accuracy = 0.6;
        let p = RoutingProblem {
            classes: vec![c],
            replicas: layout(&cfg, 2),
        };
        assert!(route_tasks(&cfg, &p).is_none());
    }

    #[test]
    fn no_deployed_pool_returns_none() {
        let cfg = Config::default();
        let p = RoutingProblem {
            classes: vec![balanced_class(1.0, None)],
            replicas: layout(&cfg, 0), // nothing deployed
        };
        assert!(route_tasks(&cfg, &p).is_none());
    }

    #[test]
    fn empty_problem_trivial() {
        let cfg = Config::default();
        let p = RoutingProblem {
            classes: vec![],
            replicas: layout(&cfg, 1),
        };
        assert_eq!(route_tasks(&cfg, &p).unwrap().len(), 0);
    }

    #[test]
    fn minimises_worst_latency() {
        let cfg = Config::default();
        let p = RoutingProblem {
            classes: vec![balanced_class(1.0, None), balanced_class(1.0, None)],
            replicas: layout(&cfg, 4),
        };
        let sol = route_tasks(&cfg, &p).unwrap();
        let worst = sol.iter().map(|p| p.latency).fold(0.0, f64::max);
        // Sanity: splitting two λ=1 classes across pools must keep worst
        // latency near the idle YOLO latency, not the overloaded one.
        assert!(worst < 2.0, "worst={worst}");
    }
}
