//! Minimal, dependency-free JSON: a `Value` tree, a recursive-descent
//! parser, and a pretty serializer. Covers the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) — enough
//! for `artifacts/manifest.json` and config override files, with
//! line/column error reporting for humans.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with position.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(ParseError {
            msg: msg.into(),
            line,
            col,
        })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!(
                "expected '{}', found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => self.err(format!("unexpected {:?}", other.map(|c| c as char))),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                other => return self.err(format!("expected ',' or '}}', found {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                other => return self.err(format!("expected ',' or ']', found {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(()).or_else(|_| self.err("truncated \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).map(Ok).unwrap_or_else(|| {
                                    self.err("invalid hex in \\u")
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => {
                        return self.err(format!("bad escape {:?}", other.map(|c| c as char)))
                    }
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map(Ok)
                            .unwrap_or_else(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map(Ok)
            .unwrap_or_else(|_| self.err(format!("invalid number '{text}'")))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad1 = "  ".repeat(indent + 1);
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (k, item) in items.iter().enumerate() {
                out.push_str(&pad1);
                write_value(item, indent + 1, out);
                if k + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (k, (key, item)) in map.iter().enumerate() {
                out.push_str(&pad1);
                escape(key, out);
                out.push_str(": ");
                write_value(item, indent + 1, out);
                if k + 1 < map.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
    }
}

/// Pretty-print a value.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (k, item) in items.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (k, (key, item)) in map.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                escape(key, out);
                out.push(':');
                write_compact(item, out);
            }
            out.push('}');
        }
    }
}

/// One-line rendering (no interior newlines) for line-delimited wire
/// protocols — the experiment fabric frames one JSON value per line.
/// Numbers format exactly as in [`to_string`], so a value round-trips
/// identically through either form.
pub fn to_compact_string(v: &Value) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = parse(
            r#"{"num_classes": 4, "models": {"m": {"flops": 129, "input_shape": [1, 64, 64, 3]}}}"#,
        )
        .unwrap();
        assert_eq!(v.get("num_classes").unwrap().as_u64(), Some(4));
        let m = v.get("models").unwrap().get("m").unwrap();
        assert_eq!(m.get("flops").unwrap().as_u64(), Some(129));
        let shape: Vec<usize> = m
            .get("input_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 64, 64, 3]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\n\"y\"", "c": true, "d": null, "e": {}}"#;
        let v = parse(src).unwrap();
        let text = to_string(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\n\"y\"", "c": true, "d": null, "e": {}}"#;
        let v = parse(src).unwrap();
        let line = to_compact_string(&v);
        assert!(!line.contains('\n'), "compact form must be one line: {line}");
        assert_eq!(parse(&line).unwrap(), v, "compact form must round-trip");
        // The escaped newline inside the string stays escaped.
        assert!(line.contains("\\n"));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""tab\there A""#).unwrap();
        assert_eq!(v.as_str(), Some("tab\there A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""å π 🤖""#).unwrap();
        assert_eq!(v.as_str(), Some("å π 🤖"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(parse("-12.5").unwrap().as_f64(), Some(-12.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("2.5E-2").unwrap().as_f64(), Some(0.025));
    }

    #[test]
    fn errors_have_position() {
        let e = parse("{\n  \"a\": oops}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("unexpected") || e.msg.contains("literal"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_depth() {
        let v = parse("[[[[[[1]]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..6 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-2").unwrap().as_u64(), None);
    }
}
