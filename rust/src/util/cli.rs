//! Tiny CLI argument parser: `--flag value`, `--flag=value`, boolean
//! switches, positionals, and generated usage text.
//!
//! Misconfiguration is an error, not a shrug (ISSUE 9): a flag given
//! twice fails at parse time, and [`Args::reject_unknown`] fails on any
//! flag that no getter consumed — so `--thread 8` can never silently
//! run a sweep single-threaded because the real flag is `--threads`.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};

/// Parsed arguments. Getters record every flag name they look up (hit
/// or miss) so [`Args::reject_unknown`] can flag the leftovers — and
/// suggest the nearest queried/allowed name for a likely typo.
#[derive(Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
    consumed: RefCell<HashSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// A repeated flag is an error: the old behaviour silently kept the
    /// last value, so `--seed 1 … --seed 2` ran a different experiment
    /// than the command line appeared to say.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self, String> {
        let mut flags: HashMap<String, String> = HashMap::new();
        let mut positional = Vec::new();
        let mut insert = |flags: &mut HashMap<String, String>, k: String, v: String| {
            if flags.insert(k.clone(), v).is_some() {
                return Err(format!(
                    "--{k} given more than once (each flag may appear once)"
                ));
            }
            Ok(())
        };
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest positional.
                    positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    insert(&mut flags, k.to_string(), v.to_string())?;
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    insert(&mut flags, name.to_string(), v)?;
                } else {
                    // Boolean switch.
                    insert(&mut flags, name.to_string(), "true".to_string())?;
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args {
            flags,
            positional,
            consumed: RefCell::new(HashSet::new()),
        })
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32, String> {
        self.get_u64(name, default as u64).map(|v| v as u32)
    }

    pub fn get_bool(&self, name: &str, default: bool) -> Result<bool, String> {
        match self.get(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{name}: expected true/false, got '{v}'")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Error on every flag that was neither consumed by a getter nor
    /// listed in `also_allowed` — the unrecognized flag is named, with a
    /// did-you-mean suggestion when a known name is within edit
    /// distance 2. Call once per subcommand, after its flags are read
    /// (or with the subcommand's full flag list up front).
    pub fn reject_unknown(&self, also_allowed: &[&str]) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let known: Vec<&str> = consumed
            .iter()
            .map(|s| s.as_str())
            .chain(also_allowed.iter().copied())
            .collect();
        let mut unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(k.as_str()) && !also_allowed.contains(&k.as_str()))
            .collect();
        unknown.sort();
        let Some(first) = unknown.first() else {
            return Ok(());
        };
        let suggestion = known
            .iter()
            .map(|k| (edit_distance(first, k), *k))
            .filter(|&(d, _)| d <= 2)
            .min()
            .map(|(_, k)| format!(" (did you mean --{k}?)"))
            .unwrap_or_default();
        Err(format!("unrecognized flag --{first}{suggestion}"))
    }

    /// All parsed flag names (wire-protocol callers that forward flags).
    pub fn flag_names(&self) -> Vec<&str> {
        self.flags.keys().map(|s| s.as_str()).collect()
    }
}

/// Levenshtein distance, small-string implementation (flag names).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flag_value_forms() {
        let a = parse(&["--lambda", "4.5", "--policy=la-imr", "--bursty"]);
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 4.5);
        assert_eq!(a.get_str("policy", ""), "la-imr");
        assert!(a.get_bool("bursty", false).unwrap());
    }

    #[test]
    fn positionals_and_subcommand() {
        let a = parse(&["repro", "table4", "--seed", "7"]);
        assert_eq!(a.positional(), &["repro", "table4"]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_f64("lambda", 4.0).unwrap(), 4.0);
        assert_eq!(a.get_str("policy", "la-imr"), "la-imr");
        assert!(!a.get_bool("bursty", false).unwrap());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--lambda", "abc"]);
        assert!(a.get_f64("lambda", 0.0).is_err());
    }

    #[test]
    fn boolean_switch_before_flag() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.get_bool("verbose", false).unwrap());
        assert_eq!(a.get_u64("n", 0).unwrap(), 3);
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["--not-a-flag"]);
        a.get("x");
        a.reject_unknown(&[]).unwrap();
    }

    #[test]
    fn misspelled_flag_is_an_error() {
        // Regression (ISSUE 9): `laimr repro table6 --thread 8` used to
        // run single-threaded with no warning — the typo was silently
        // ignored. It must now error, naming the flag and suggesting
        // the real one.
        let a = parse(&["repro", "table6", "--thread", "8"]);
        // The program reads the flags it knows about...
        assert_eq!(a.get_u64("threads", 0).unwrap(), 0);
        // ...and the leftover typo is rejected by name.
        let err = a.reject_unknown(&[]).unwrap_err();
        assert!(err.contains("--thread"), "flag not named: {err}");
        assert!(
            err.contains("did you mean --threads"),
            "no suggestion: {err}"
        );
    }

    #[test]
    fn unknown_flag_without_near_miss_still_named() {
        let a = parse(&["--frobnicate", "1"]);
        a.get("threads");
        let err = a.reject_unknown(&[]).unwrap_err();
        assert!(err.contains("--frobnicate"), "flag not named: {err}");
    }

    #[test]
    fn allowed_list_counts_as_consumed() {
        let a = parse(&["--dir", "scenarios"]);
        a.reject_unknown(&["dir"]).unwrap();
    }

    #[test]
    fn duplicate_flag_is_an_error() {
        // Regression (ISSUE 9): a repeated flag used to silently keep
        // the last value.
        let err = Args::parse(
            ["--seed", "1", "--seed", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap_err();
        assert!(err.contains("--seed"), "flag not named: {err}");
        assert!(err.contains("more than once"), "cause unclear: {err}");
        // `--flag=v` and `--flag v` forms collide too.
        let err = Args::parse(
            ["--seed=1", "--seed", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap_err();
        assert!(err.contains("--seed"), "flag not named: {err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("thread", "threads"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
