//! Tiny CLI argument parser: `--flag value`, `--flag=value`, boolean
//! switches, positionals, and generated usage text.

use std::collections::HashMap;

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self, String> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest positional.
                    positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(name.to_string(), v);
                } else {
                    // Boolean switch.
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { flags, positional })
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32, String> {
        self.get_u64(name, default as u64).map(|v| v as u32)
    }

    pub fn get_bool(&self, name: &str, default: bool) -> Result<bool, String> {
        match self.get(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{name}: expected true/false, got '{v}'")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flag_value_forms() {
        let a = parse(&["--lambda", "4.5", "--policy=la-imr", "--bursty"]);
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 4.5);
        assert_eq!(a.get_str("policy", ""), "la-imr");
        assert!(a.get_bool("bursty", false).unwrap());
    }

    #[test]
    fn positionals_and_subcommand() {
        let a = parse(&["repro", "table4", "--seed", "7"]);
        assert_eq!(a.positional(), &["repro", "table4"]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_f64("lambda", 4.0).unwrap(), 4.0);
        assert_eq!(a.get_str("policy", "la-imr"), "la-imr");
        assert!(!a.get_bool("bursty", false).unwrap());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--lambda", "abc"]);
        assert!(a.get_f64("lambda", 0.0).is_err());
    }

    #[test]
    fn boolean_switch_before_flag() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.get_bool("verbose", false).unwrap());
        assert_eq!(a.get_u64("n", 0).unwrap(), 3);
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["--not-a-flag"]);
    }
}
