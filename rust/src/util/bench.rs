//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean / P50 / P99 per-op reporting, plus a
//! `black_box` to defeat constant folding. Used by every `cargo bench`
//! target under rust/benches/.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Opaque value barrier (re-export for benches).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}   min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure: auto-chooses batch size so each sample is ≥ ~1 ms,
/// collects ≥ `samples` samples, reports per-op statistics.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> Measurement {
    // Warm-up + batch size calibration.
    let mut batch = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= 1e-3 || batch >= 1 << 24 {
            break;
        }
        batch = (batch * 4).min(1 << 24);
    }

    let samples = samples.max(5);
    let mut per_op: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        per_op.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    per_op.sort_by(f64::total_cmp);
    let mean = per_op.iter().sum::<f64>() / per_op.len() as f64;
    let idx = |q: f64| ((per_op.len() - 1) as f64 * q).round() as usize;
    let m = Measurement {
        name: name.to_string(),
        iters: batch * samples as u64,
        mean_ns: mean,
        p50_ns: per_op[idx(0.5)],
        p99_ns: per_op[idx(0.99)],
        min_ns: per_op[0],
    };
    m.report();
    m
}

/// Peak resident set size of this process [bytes], from Linux
/// `/proc/self/status` (`VmHWM`, the RSS high-water mark). `None` off
/// Linux or if the field is missing — callers should report "n/a"
/// rather than fail. Used by the million-robot bench to show that the
/// chunk-streamed arrival front end bounds peak memory.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    // Format: "VmHWM:    123456 kB".
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Time a single (possibly slow) run — for end-to-end scenario benches
/// where one run is seconds of virtual workload.
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{name:<44} 1 run    {dt:.3} s");
    (out, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_cheap_op() {
        let mut x = 0u64;
        let m = bench("noop-add", 5, || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.p50_ns <= m.p99_ns + 1e-9);
        assert!(m.min_ns <= m.mean_ns);
        assert!(x > 0);
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, dt) = bench_once("const", || 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn peak_rss_sane_on_linux() {
        if let Some(bytes) = peak_rss_bytes() {
            // Any live process has at least a page resident; gigantic
            // values would mean we parsed the wrong field.
            assert!(bytes >= 4096, "peak RSS {bytes} implausibly small");
            assert!(bytes < 1 << 45, "peak RSS {bytes} implausibly large");
        } else {
            assert!(!cfg!(target_os = "linux"), "VmHWM must parse on Linux");
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
