//! Compact binary `SimResult` codec (ISSUE 10).
//!
//! Length-prefixed little-endian encoding of a full [`SimResult`], used
//! by the persistent result store (`sim/store.rs`) and as the opt-in
//! fabric worker frame payload (`--frame-format binary`). Floats are
//! carried as raw IEEE-754 bit patterns (`to_le_bytes` of `to_bits()`),
//! the event-log convention: byte-identical payloads mean bit-identical
//! results, NaN/±inf/-0.0 included, and no decimal-formatting subtlety
//! can smuggle a difference through. The encoding is differential-tested
//! against the PR-9 `fabric::result_to_json`/`result_from_json` path
//! (`tests/result_store.rs`).
//!
//! Robustness contract: [`decode_result`] never panics. Truncated,
//! bit-flipped, or trailing-garbage input decodes to a named
//! [`CodecError`]; declared lengths are sanity-checked against the
//! remaining byte budget before any allocation, so a corrupted count
//! cannot trigger an OOM.

use crate::config::QualityClass;
use crate::sim::{CompletedRequest, ShedRecord, ShedReason, SimResult, TailCounters};

/// Format magic + version. Bump the trailing digit on any layout change;
/// old entries then decode to [`CodecError::BadMagic`] and are treated
/// as stale, never misread.
pub const MAGIC: &[u8; 4] = b"LRC1";

/// Minimum encoded size of one completed-request record
/// (id + arrived + finished + quality + offloaded).
const COMPLETED_RECORD_LEN: usize = 8 + 8 + 8 + 1 + 1;
/// Minimum encoded size of one shed record
/// (id + at + quality + reason + predicted).
const SHED_RECORD_LEN: usize = 8 + 8 + 1 + 1 + 8;

/// Named decode failure. Every variant is a *diagnosis*, not a panic:
/// the store and the fabric treat any of these as "recompute the cell".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Wrong magic/version prefix (stale format or not a codec payload).
    BadMagic,
    /// Input ended before `field` could be read in full.
    Truncated {
        field: &'static str,
        need: usize,
        have: usize,
    },
    /// A declared count/length exceeds the bytes actually present.
    BadLength { field: &'static str },
    /// An enum tag byte outside the known discriminants.
    BadEnum { field: &'static str, value: u8 },
    /// A boolean byte that is neither 0 nor 1.
    BadBool { field: &'static str, value: u8 },
    /// A string field that is not valid UTF-8.
    BadUtf8 { field: &'static str },
    /// Bytes left over after a complete result was decoded.
    TrailingBytes { extra: usize },
    /// Invalid base64 text (bad character, bad padding, or bad length).
    BadBase64 { reason: &'static str },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::BadMagic => {
                write!(f, "bad magic (not a {} payload or a stale format version)",
                    String::from_utf8_lossy(MAGIC))
            }
            CodecError::Truncated { field, need, have } => {
                write!(f, "truncated at '{field}': need {need} bytes, have {have}")
            }
            CodecError::BadLength { field } => {
                write!(f, "declared length of '{field}' exceeds the payload")
            }
            CodecError::BadEnum { field, value } => {
                write!(f, "unknown '{field}' discriminant {value}")
            }
            CodecError::BadBool { field, value } => {
                write!(f, "'{field}' byte {value} is not a boolean (0|1)")
            }
            CodecError::BadUtf8 { field } => write!(f, "'{field}' is not valid UTF-8"),
            CodecError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing byte(s) after a complete result")
            }
            CodecError::BadBase64 { reason } => write!(f, "bad base64: {reason}"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u32::MAX as usize, "scenario/policy names are short");
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn quality_tag(q: QualityClass) -> u8 {
    // Dispatch priority doubles as the stable wire discriminant.
    q.priority() as u8
}

fn reason_tag(r: ShedReason) -> u8 {
    match r {
        ShedReason::DeadlineBreach => 0,
        ShedReason::Unstable => 1,
    }
}

/// Encode a full result. Infallible: every `SimResult` the engine can
/// produce has a representation.
pub fn encode_result(r: &SimResult) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        128 + r.scenario_name.len()
            + r.policy_name.len()
            + COMPLETED_RECORD_LEN * r.completed.len()
            + SHED_RECORD_LEN * r.shed.len(),
    );
    out.extend_from_slice(MAGIC);
    put_str(&mut out, &r.scenario_name);
    put_str(&mut out, &r.policy_name);
    put_u64(&mut out, r.generated as u64);
    put_u64(&mut out, r.unfinished as u64);
    put_u64(&mut out, r.unfinished_post_warmup as u64);
    put_u64(&mut out, r.scale_outs);
    put_u64(&mut out, r.scale_ins);
    put_u32(&mut out, r.peak_replicas);
    put_f64(&mut out, r.mean_replicas);
    put_u64(&mut out, r.crashes);
    put_u64(&mut out, r.events);
    put_u64(&mut out, r.fluid_batched);
    let t = &r.tail;
    put_u64(&mut out, t.copies_enqueued);
    put_u64(&mut out, t.hedges_launched);
    put_u64(&mut out, t.shed);
    put_u64(&mut out, t.wins);
    put_u64(&mut out, t.losers_finished);
    put_u64(&mut out, t.cancelled);
    put_u64(&mut out, t.stale_dropped);
    put_u64(&mut out, t.crash_tombstoned);
    put_u64(&mut out, t.residual_copies);
    put_f64(&mut out, t.busy_time);
    put_f64(&mut out, t.wasted_time);
    put_u64(&mut out, r.completed.len() as u64);
    for c in &r.completed {
        put_u64(&mut out, c.id);
        put_f64(&mut out, c.arrived);
        put_f64(&mut out, c.finished);
        out.push(quality_tag(c.quality));
        out.push(u8::from(c.offloaded));
    }
    put_u64(&mut out, r.shed.len() as u64);
    for s in &r.shed {
        put_u64(&mut out, s.id);
        put_f64(&mut out, s.at);
        out.push(quality_tag(s.quality));
        out.push(reason_tag(s.reason));
        put_f64(&mut out, s.predicted);
    }
    out
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked reader over the payload. Every `take_*` returns a
/// named error instead of indexing past the end.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated {
                field,
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u32(&mut self, field: &'static str) -> Result<u32, CodecError> {
        let b = self.take(4, field)?;
        Ok(u32::from_le_bytes(b.try_into().expect("4 bytes")))
    }

    fn take_u64(&mut self, field: &'static str) -> Result<u64, CodecError> {
        let b = self.take(8, field)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn take_f64(&mut self, field: &'static str) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.take_u64(field)?))
    }

    fn take_str(&mut self, field: &'static str) -> Result<String, CodecError> {
        let len = self.take_u32(field)? as usize;
        if len > self.remaining() {
            return Err(CodecError::BadLength { field });
        }
        let bytes = self.take(len, field)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::BadUtf8 { field })
    }

    fn take_bool(&mut self, field: &'static str) -> Result<bool, CodecError> {
        match self.take(1, field)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            value => Err(CodecError::BadBool { field, value }),
        }
    }

    fn take_quality(&mut self, field: &'static str) -> Result<QualityClass, CodecError> {
        match self.take(1, field)?[0] {
            0 => Ok(QualityClass::LowLatency),
            1 => Ok(QualityClass::Balanced),
            2 => Ok(QualityClass::Precise),
            value => Err(CodecError::BadEnum { field, value }),
        }
    }

    fn take_reason(&mut self, field: &'static str) -> Result<ShedReason, CodecError> {
        match self.take(1, field)?[0] {
            0 => Ok(ShedReason::DeadlineBreach),
            1 => Ok(ShedReason::Unstable),
            value => Err(CodecError::BadEnum { field, value }),
        }
    }

    /// A declared record count, capped by what could physically fit in
    /// the remaining bytes — a corrupted count can neither over-allocate
    /// nor spin the decode loop.
    fn take_count(
        &mut self,
        field: &'static str,
        min_record_len: usize,
    ) -> Result<usize, CodecError> {
        let n = self.take_u64(field)?;
        if n > (self.remaining() / min_record_len) as u64 {
            return Err(CodecError::BadLength { field });
        }
        Ok(n as usize)
    }
}

/// Decode a payload produced by [`encode_result`], bit-identical to the
/// original. Never panics; malformed input yields a named [`CodecError`].
pub fn decode_result(bytes: &[u8]) -> Result<SimResult, CodecError> {
    let mut c = Cursor::new(bytes);
    if c.take(MAGIC.len(), "magic")? != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let scenario_name = c.take_str("scenario_name")?;
    let policy_name = c.take_str("policy_name")?;
    let generated = c.take_u64("generated")? as usize;
    let unfinished = c.take_u64("unfinished")? as usize;
    let unfinished_post_warmup = c.take_u64("unfinished_post_warmup")? as usize;
    let scale_outs = c.take_u64("scale_outs")?;
    let scale_ins = c.take_u64("scale_ins")?;
    let peak_replicas = c.take_u32("peak_replicas")?;
    let mean_replicas = c.take_f64("mean_replicas")?;
    let crashes = c.take_u64("crashes")?;
    let events = c.take_u64("events")?;
    let fluid_batched = c.take_u64("fluid_batched")?;
    let tail = TailCounters {
        copies_enqueued: c.take_u64("tail.copies_enqueued")?,
        hedges_launched: c.take_u64("tail.hedges_launched")?,
        shed: c.take_u64("tail.shed")?,
        wins: c.take_u64("tail.wins")?,
        losers_finished: c.take_u64("tail.losers_finished")?,
        cancelled: c.take_u64("tail.cancelled")?,
        stale_dropped: c.take_u64("tail.stale_dropped")?,
        crash_tombstoned: c.take_u64("tail.crash_tombstoned")?,
        residual_copies: c.take_u64("tail.residual_copies")?,
        busy_time: c.take_f64("tail.busy_time")?,
        wasted_time: c.take_f64("tail.wasted_time")?,
    };
    let n_completed = c.take_count("completed.len", COMPLETED_RECORD_LEN)?;
    let mut completed = Vec::with_capacity(n_completed);
    for _ in 0..n_completed {
        completed.push(CompletedRequest {
            id: c.take_u64("completed.id")?,
            arrived: c.take_f64("completed.arrived")?,
            finished: c.take_f64("completed.finished")?,
            quality: c.take_quality("completed.quality")?,
            offloaded: c.take_bool("completed.offloaded")?,
        });
    }
    let n_shed = c.take_count("shed.len", SHED_RECORD_LEN)?;
    let mut shed = Vec::with_capacity(n_shed);
    for _ in 0..n_shed {
        shed.push(ShedRecord {
            id: c.take_u64("shed.id")?,
            at: c.take_f64("shed.at")?,
            quality: c.take_quality("shed.quality")?,
            reason: c.take_reason("shed.reason")?,
            predicted: c.take_f64("shed.predicted")?,
        });
    }
    if c.remaining() > 0 {
        return Err(CodecError::TrailingBytes {
            extra: c.remaining(),
        });
    }
    Ok(SimResult {
        scenario_name,
        policy_name,
        completed,
        generated,
        unfinished,
        unfinished_post_warmup,
        scale_outs,
        scale_ins,
        peak_replicas,
        mean_replicas,
        crashes,
        events,
        shed,
        tail,
        fluid_batched,
        cache: Default::default(),
    })
}

// ---------------------------------------------------------------------------
// Base64 (binary payloads inside line-delimited JSON frames)
// ---------------------------------------------------------------------------

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 with `=` padding: the binary frame format rides the
/// existing one-line JSON envelope, so the fabric's chaos/respawn
/// machinery is format-agnostic.
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(triple >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(triple >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            B64_ALPHABET[(triple >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            B64_ALPHABET[triple as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

fn b64_value(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode standard padded base64; any malformation is a named error,
/// never a panic.
pub fn b64_decode(s: &str) -> Result<Vec<u8>, CodecError> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(CodecError::BadBase64 {
            reason: "length is not a multiple of 4",
        });
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (k, quad) in bytes.chunks(4).enumerate() {
        let last = k + 1 == bytes.len() / 4;
        let pad = quad.iter().rev().take_while(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !last) {
            return Err(CodecError::BadBase64 {
                reason: "padding only allowed at the end (at most 2 bytes)",
            });
        }
        let mut triple: u32 = 0;
        for &c in &quad[..4 - pad] {
            let v = b64_value(c).ok_or(CodecError::BadBase64 {
                reason: "character outside the base64 alphabet",
            })?;
            triple = (triple << 6) | v;
        }
        triple <<= 6 * pad as u32;
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic result exercising every field, including
    /// non-representable float sums, specials, and u64 values past 2^53
    /// (the cases the JSON wire format carries as strings).
    fn sample() -> SimResult {
        SimResult {
            scenario_name: "codec-test".into(),
            policy_name: "la-imr".into(),
            completed: vec![
                CompletedRequest {
                    id: 3,
                    arrived: 0.1 + 0.2,
                    finished: 1.0 / 3.0,
                    quality: QualityClass::LowLatency,
                    offloaded: true,
                },
                CompletedRequest {
                    id: (1 << 60) + 7,
                    arrived: f64::MIN_POSITIVE,
                    finished: 1e308,
                    quality: QualityClass::Precise,
                    offloaded: false,
                },
            ],
            generated: 5,
            unfinished: 1,
            unfinished_post_warmup: 1,
            scale_outs: 2,
            scale_ins: 1,
            peak_replicas: 4,
            mean_replicas: 2.5000000000000004,
            crashes: 1,
            events: (1 << 53) + 1,
            shed: vec![ShedRecord {
                id: 9,
                at: 2.5,
                quality: QualityClass::Balanced,
                reason: ShedReason::Unstable,
                predicted: 0.30000000000000004,
            }],
            tail: TailCounters {
                copies_enqueued: 7,
                hedges_launched: 2,
                shed: 1,
                wins: 4,
                losers_finished: 1,
                cancelled: 1,
                stale_dropped: 0,
                crash_tombstoned: 1,
                residual_copies: 0,
                busy_time: 1.1,
                wasted_time: 0.1 * 3.0,
            },
            fluid_batched: 3,
            cache: Default::default(),
        }
    }

    fn assert_bits_equal(a: &SimResult, b: &SimResult) {
        assert_eq!(a.scenario_name, b.scenario_name);
        assert_eq!(a.policy_name, b.policy_name);
        assert_eq!(a.generated, b.generated);
        assert_eq!(a.unfinished, b.unfinished);
        assert_eq!(a.unfinished_post_warmup, b.unfinished_post_warmup);
        assert_eq!(a.scale_outs, b.scale_outs);
        assert_eq!(a.scale_ins, b.scale_ins);
        assert_eq!(a.peak_replicas, b.peak_replicas);
        assert_eq!(a.mean_replicas.to_bits(), b.mean_replicas.to_bits());
        assert_eq!(a.crashes, b.crashes);
        assert_eq!(a.events, b.events);
        assert_eq!(a.fluid_batched, b.fluid_batched);
        assert_eq!(a.tail, b.tail);
        assert_eq!(a.tail.busy_time.to_bits(), b.tail.busy_time.to_bits());
        assert_eq!(a.tail.wasted_time.to_bits(), b.tail.wasted_time.to_bits());
        assert_eq!(a.completed.len(), b.completed.len());
        for (x, y) in a.completed.iter().zip(&b.completed) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrived.to_bits(), y.arrived.to_bits());
            assert_eq!(x.finished.to_bits(), y.finished.to_bits());
            assert_eq!(x.quality, y.quality);
            assert_eq!(x.offloaded, y.offloaded);
        }
        assert_eq!(a.shed.len(), b.shed.len());
        for (x, y) in a.shed.iter().zip(&b.shed) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.at.to_bits(), y.at.to_bits());
            assert_eq!(x.quality, y.quality);
            assert_eq!(x.reason, y.reason);
            assert_eq!(x.predicted.to_bits(), y.predicted.to_bits());
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let r = sample();
        let bytes = encode_result(&r);
        let back = decode_result(&bytes).unwrap();
        assert_bits_equal(&r, &back);
        // Deterministic encoding: same result, same bytes.
        assert_eq!(bytes, encode_result(&back));
    }

    #[test]
    fn float_specials_roundtrip_by_bits() {
        let mut r = sample();
        r.mean_replicas = f64::NAN;
        r.tail.busy_time = f64::INFINITY;
        r.tail.wasted_time = f64::NEG_INFINITY;
        r.completed[0].arrived = -0.0;
        r.shed[0].predicted = f64::from_bits(0x7ff8_dead_beef_0001); // payload NaN
        let back = decode_result(&encode_result(&r)).unwrap();
        assert_bits_equal(&r, &back);
    }

    #[test]
    fn empty_result_roundtrips() {
        let mut r = sample();
        r.completed.clear();
        r.shed.clear();
        r.scenario_name = String::new();
        let back = decode_result(&encode_result(&r)).unwrap();
        assert_bits_equal(&r, &back);
    }

    #[test]
    fn every_truncation_is_a_named_error_not_a_panic() {
        let bytes = encode_result(&sample());
        for n in 0..bytes.len() {
            let err = decode_result(&bytes[..n])
                .expect_err("a strict prefix can never be a complete result");
            // Any named variant is fine; the point is no panic and no Ok.
            assert!(!err.to_string().is_empty(), "truncation at {n}");
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = encode_result(&sample());
        bytes.push(0x00);
        assert_eq!(
            decode_result(&bytes),
            Err(CodecError::TrailingBytes { extra: 1 })
        );
    }

    #[test]
    fn bad_magic_and_bad_enums_are_named() {
        let mut bytes = encode_result(&sample());
        bytes[0] ^= 0xFF;
        assert_eq!(decode_result(&bytes), Err(CodecError::BadMagic));

        // Corrupt the first completed record's quality tag (fixed offset:
        // walk the header to find it).
        let r = sample();
        let bytes = encode_result(&r);
        let header = MAGIC.len()
            + 4 + r.scenario_name.len()
            + 4 + r.policy_name.len()
            + 8 * 7 + 4 + 8 // counters through mean_replicas
            + 8 * 3 // crashes, events, fluid_batched
            + 8 * 9 + 8 * 2 // tail
            + 8; // completed.len
        let quality_at = header + 8 + 8 + 8;
        let mut bad = bytes.clone();
        bad[quality_at] = 9;
        match decode_result(&bad) {
            Err(CodecError::BadEnum { field, value: 9 }) => {
                assert_eq!(field, "completed.quality")
            }
            other => panic!("expected BadEnum, got {other:?}"),
        }
        let mut bad = bytes;
        bad[quality_at + 1] = 7; // offloaded flag
        match decode_result(&bad) {
            Err(CodecError::BadBool { field, value: 7 }) => {
                assert_eq!(field, "completed.offloaded")
            }
            other => panic!("expected BadBool, got {other:?}"),
        }
    }

    #[test]
    fn garbage_counts_cannot_overallocate() {
        // Claim 2^62 completed records in an otherwise-valid header: the
        // count is capped by the remaining byte budget and rejected.
        let r = sample();
        let bytes = encode_result(&r);
        let count_at = MAGIC.len()
            + 4 + r.scenario_name.len()
            + 4 + r.policy_name.len()
            + 8 * 7 + 4 + 8
            + 8 * 3
            + 8 * 9 + 8 * 2;
        let mut bad = bytes;
        bad[count_at..count_at + 8].copy_from_slice(&(1u64 << 62).to_le_bytes());
        assert_eq!(
            decode_result(&bad),
            Err(CodecError::BadLength {
                field: "completed.len"
            })
        );
    }

    #[test]
    fn random_byte_flips_never_panic() {
        // Fuzz-ish corpus: flip every byte of a valid encoding, one at a
        // time. Each mutant must decode to Ok (benign flip, e.g. inside
        // a float) or a named error — never panic.
        let bytes = encode_result(&sample());
        for i in 0..bytes.len() {
            let mut m = bytes.clone();
            m[i] ^= 0x5A;
            let _ = decode_result(&m);
        }
    }

    #[test]
    fn base64_roundtrips() {
        for data in [
            &b""[..],
            &b"f"[..],
            &b"fo"[..],
            &b"foo"[..],
            &b"foob"[..],
            &b"fooba"[..],
            &b"foobar"[..],
            &[0u8, 255, 128, 7, 63][..],
        ] {
            let enc = b64_encode(data);
            assert_eq!(b64_decode(&enc).unwrap(), data, "corpus {data:?}");
        }
        // Known vector (RFC 4648).
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(b64_encode(b"foob"), "Zm9vYg==");
    }

    #[test]
    fn base64_rejects_malformed_input() {
        assert!(b64_decode("abc").is_err(), "length not multiple of 4");
        assert!(b64_decode("ab!=").is_err(), "bad character");
        assert!(b64_decode("a===").is_err(), "over-padding");
        assert!(b64_decode("ab==cdef").is_err(), "interior padding");
    }

    #[test]
    fn encoded_result_survives_base64_transport() {
        let r = sample();
        let bytes = encode_result(&r);
        let wire = b64_encode(&bytes);
        let back = decode_result(&b64_decode(&wire).unwrap()).unwrap();
        assert_bits_equal(&r, &back);
    }
}
