//! In-tree utility substrates. The build environment is fully offline
//! (only the `xla` crate's vendored tree is available), so the pieces a
//! serving framework would normally pull from crates.io are implemented
//! here: a JSON parser/serializer (config + artifact manifest), a CLI
//! argument parser, a micro-benchmark harness used by `cargo bench`, and
//! the compact binary `SimResult` codec backing the persistent result
//! store and the opt-in binary fabric frame format (ISSUE 10).

pub mod bench;
pub mod cli;
pub mod codec;
pub mod json;
pub mod sha256;
