//! In-tree utility substrates. The build environment is fully offline
//! (only the `xla` crate's vendored tree is available), so the pieces a
//! serving framework would normally pull from crates.io are implemented
//! here: a JSON parser/serializer (config + artifact manifest), a CLI
//! argument parser, and a micro-benchmark harness used by `cargo bench`.

pub mod bench;
pub mod cli;
pub mod json;
pub mod sha256;
