//! Minimal SHA-256 (FIPS 180-4) — in-tree because the build is offline
//! (no crates.io), used to fingerprint scenario documents and event-log
//! headers so published results are replayable and tamper-evident
//! (ISSUE 8). Not a performance path: it hashes kilobytes of JSON, not
//! request traffic.

/// Round constants: first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash values: first 32 bits of the fractional parts of the
/// square roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256 hasher (`update` any number of times, then
/// `finish`).
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding trailer needs bits).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            Self::compress(&mut self.state, &block);
            data = &data[64..];
        }
        // Stash the tail.
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finish(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // 0x80 terminator, zero fill, 64-bit big-endian bit length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Manual trailer: `update` would recount the length.
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        let mut out = [0u8; 32];
        for (k, w) in self.state.iter().enumerate() {
            out[4 * k..4 * k + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (k, chunk) in block.chunks_exact(4).enumerate() {
            w[k] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for k in 16..64 {
            let s0 = w[k - 15].rotate_right(7) ^ w[k - 15].rotate_right(18) ^ (w[k - 15] >> 3);
            let s1 = w[k - 2].rotate_right(17) ^ w[k - 2].rotate_right(19) ^ (w[k - 2] >> 10);
            w[k] = w[k - 16]
                .wrapping_add(s0)
                .wrapping_add(w[k - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
        for k in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[k])
                .wrapping_add(w[k]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
        state[4] = state[4].wrapping_add(e);
        state[5] = state[5].wrapping_add(f);
        state[6] = state[6].wrapping_add(g);
        state[7] = state[7].wrapping_add(h);
    }
}

/// Lowercase hex rendering of a digest.
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// One-shot SHA-256 → lowercase hex.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    hex(&h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // 56 bytes: the padding spills into a second block.
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        assert_eq!(
            sha256_hex(b"The quick brown fox jumps over the lazy dog"),
            "d7a8fbb307d7809469ca9abcb0082e4f8d5651e46d3cdb762d02d0bf37c9e592"
        );
    }

    #[test]
    fn chunked_update_matches_one_shot() {
        let data: Vec<u8> = (0u32..1000).map(|k| (k % 251) as u8).collect();
        let one_shot = sha256_hex(&data);
        for chunk in [1usize, 3, 63, 64, 65, 130] {
            let mut h = Sha256::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(hex(&h.finish()), one_shot, "chunk size {chunk}");
        }
    }

    #[test]
    fn million_a_vector() {
        // FIPS long vector: 10⁶ × 'a'.
        let mut h = Sha256::new();
        let block = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&block);
        }
        assert_eq!(
            hex(&h.finish()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }
}
