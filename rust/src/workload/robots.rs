//! Robot fleet client model — the CloudGripper stand-in (§V-A.1).
//!
//! Each robot is a camera-bearing manipulation cell that emits frames at
//! a configurable rate and waits for detection results. The serving
//! examples drive the real PJRT runtime with these synthetic frames; the
//! DES only needs the arrival times.

use crate::config::QualityClass;
use crate::rng::Rng;

/// One CloudGripper-style work cell.
#[derive(Debug, Clone)]
pub struct Robot {
    pub id: usize,
    /// Frames per second this robot emits (≤ 30 per the testbed cameras).
    pub fps: f64,
    /// Quality lane its requests ride in.
    pub quality: QualityClass,
}

/// A fleet of robots with synthetic frame generation.
#[derive(Debug, Clone)]
pub struct RobotFleet {
    pub robots: Vec<Robot>,
}

impl RobotFleet {
    /// `n` identical robots, each at `fps`, all on one lane — the paper's
    /// experiment shape ("the number of robots issuing requests" is the
    /// swept variable, all served by YOLOv5m).
    pub fn uniform(n: usize, fps: f64, quality: QualityClass) -> Self {
        RobotFleet {
            robots: (0..n).map(|id| Robot { id, fps, quality }).collect(),
        }
    }

    /// Aggregate request rate [req/s].
    pub fn aggregate_rate(&self) -> f64 {
        self.robots.iter().map(|r| r.fps).sum()
    }

    /// Synthesise one camera frame as a flat NHWC f32 tensor in [0,1]:
    /// a textured background + a bright square "object" whose position is
    /// derived from (robot id, frame index) — deterministic, non-trivial
    /// input for the real detector models.
    pub fn frame(&self, robot: usize, frame_idx: u64, hw: usize) -> Vec<f32> {
        let mut rng = Rng::new((robot as u64) << 32 | frame_idx);
        let c = 3usize;
        let mut img = vec![0.0f32; hw * hw * c];
        // Textured background.
        for px in img.iter_mut() {
            *px = 0.2 + 0.1 * rng.uniform() as f32;
        }
        // Object: bright square, position jitters per frame.
        let size = hw / 6;
        let ox = rng.below(hw - size);
        let oy = rng.below(hw - size);
        for y in oy..oy + size {
            for x in ox..ox + size {
                let base = (y * hw + x) * c;
                img[base] = 0.9;
                img[base + 1] = 0.7;
                img[base + 2] = 0.3;
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_fleet_rate() {
        let f = RobotFleet::uniform(5, 1.2, QualityClass::Balanced);
        assert_eq!(f.robots.len(), 5);
        assert!((f.aggregate_rate() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn frame_shape_and_range() {
        let f = RobotFleet::uniform(1, 1.0, QualityClass::Balanced);
        let img = f.frame(0, 0, 64);
        assert_eq!(img.len(), 64 * 64 * 3);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Contains both background and object intensities.
        assert!(img.iter().any(|&v| v > 0.8));
        assert!(img.iter().any(|&v| v < 0.4));
    }

    #[test]
    fn frames_deterministic_but_varying() {
        let f = RobotFleet::uniform(2, 1.0, QualityClass::Balanced);
        assert_eq!(f.frame(0, 0, 32), f.frame(0, 0, 32));
        assert_ne!(f.frame(0, 0, 32), f.frame(0, 1, 32));
        assert_ne!(f.frame(0, 0, 32), f.frame(1, 0, 32));
    }
}
