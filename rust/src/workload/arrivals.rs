//! Arrival-process generators for every `ArrivalKind` in the scenario
//! config: Poisson, bounded-Pareto burst trains (paper §V-D), periodic,
//! step profiles, diurnal (sinusoidal-envelope) profiles, regime-
//! switching MMPP bursts, and deterministic trace replay.
//!
//! Two front ends share the same per-kind samplers:
//!
//! * [`ArrivalGenerator`] materialises the whole stream up front — the
//!   historical API, still used by tests and reports, and the reference
//!   oracle for the streaming path.
//! * [`ArrivalStream`] emits the stream in time-banded chunks so peak
//!   memory scales with the chunk span (≈ one calendar-queue epoch), not
//!   with the total request count — the million-robot fast path. Time
//!   draws consume `Rng::new(seed)` in *exactly* the materialised order
//!   (overshoot draws are stashed across chunk boundaries; overlapping
//!   burst trains are re-merged by (time, generation-order) to match the
//!   stable sort), so the emitted times are bit-identical to
//!   `ArrivalGenerator::generate`. Quality classes come from a second,
//!   salted stream (`seed ^ QUALITY_SALT`, one uniform per arrival in
//!   emission order) — for the default `[0, 1, 0]` mix every arrival is
//!   `Balanced` either way, so default-mix scenarios stay bit-identical
//!   across both front ends.

use crate::config::{ArrivalKind, QualityClass, ScenarioConfig};
use crate::rng::Rng;
use crate::SimTime;

/// One generated request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub at: SimTime,
    pub quality: QualityClass,
}

/// Salt separating the quality-class stream from the time stream so the
/// time draws can be chunk-streamed without buffering the whole horizon.
const QUALITY_SALT: u64 = 0x0051_C1A5_5A17_ED01;

fn classify(u: f64, mix: [f64; 3]) -> QualityClass {
    if u < mix[0] {
        QualityClass::LowLatency
    } else if u < mix[0] + mix[1] {
        QualityClass::Balanced
    } else {
        QualityClass::Precise
    }
}

/// Materialise the sorted time stream for `scenario`, consuming `rng`
/// draws in the canonical per-kind order. Shared by both front ends
/// (the streamer uses it for kinds whose draw order cannot be banded by
/// time: step profiles, and unsorted replay traces).
fn materialise_times(scenario: &ScenarioConfig, rng: &mut Rng) -> Vec<SimTime> {
    let mut times: Vec<SimTime> = Vec::new();
    match &scenario.arrivals {
        ArrivalKind::Poisson { lambda } => {
            let mut t = 0.0;
            if *lambda > 0.0 {
                loop {
                    t += rng.exp(*lambda);
                    if t >= scenario.duration {
                        break;
                    }
                    times.push(t);
                }
            }
        }
        ArrivalKind::Periodic { rate } => {
            if *rate > 0.0 {
                let period = 1.0 / rate;
                let mut t = period;
                while t < scenario.duration {
                    times.push(t);
                    t += period;
                }
            }
        }
        ArrivalKind::BoundedParetoBursts {
            burst_rate,
            alpha,
            lo,
            hi,
            intra_gap,
        } => {
            let mut t = 0.0;
            if *burst_rate > 0.0 {
                loop {
                    t += rng.exp(*burst_rate);
                    if t >= scenario.duration {
                        break;
                    }
                    let size = rng.bounded_pareto(*alpha, *lo, *hi).round() as usize;
                    for k in 0..size.max(1) {
                        let at = t + k as f64 * intra_gap;
                        if at < scenario.duration {
                            times.push(at);
                        }
                    }
                }
            }
        }
        ArrivalKind::Steps { steps } => {
            for (idx, &(start, rate)) in steps.iter().enumerate() {
                let end = steps
                    .get(idx + 1)
                    .map(|s| s.0)
                    .unwrap_or(scenario.duration)
                    .min(scenario.duration);
                if rate <= 0.0 {
                    continue;
                }
                let mut t = start;
                loop {
                    t += rng.exp(rate);
                    if t >= end {
                        break;
                    }
                    times.push(t);
                }
            }
        }
        ArrivalKind::Diurnal {
            base,
            amplitude,
            period,
            phase,
        } => {
            // Thinning (Lewis–Shedler): draw a homogeneous Poisson at
            // the peak rate, accept each point with probability
            // λ(t)/peak — an *exact* non-homogeneous Poisson sample.
            let peak = base * (1.0 + amplitude);
            if peak > 0.0 {
                let two_pi = 2.0 * std::f64::consts::PI;
                let mut t = 0.0;
                loop {
                    t += rng.exp(peak);
                    if t >= scenario.duration {
                        break;
                    }
                    let rate = base * (1.0 + amplitude * (two_pi * t / period + phase).sin());
                    if rng.uniform() * peak < rate {
                        times.push(t);
                    }
                }
            }
        }
        ArrivalKind::Mmpp { rates, dwell } => {
            if !rates.is_empty() {
                let mut s = 0usize;
                let mut t = 0.0;
                while t < scenario.duration {
                    let seg_end = (t + rng.exp(1.0 / dwell[s])).min(scenario.duration);
                    if rates[s] > 0.0 {
                        let mut a = t;
                        loop {
                            a += rng.exp(rates[s]);
                            if a >= seg_end {
                                break;
                            }
                            times.push(a);
                        }
                    }
                    t = seg_end;
                    // Jump uniformly to one of the *other* regimes
                    // (alternation when there are two).
                    if rates.len() > 1 {
                        let mut next = rng.below(rates.len() - 1);
                        if next >= s {
                            next += 1;
                        }
                        s = next;
                    }
                }
            }
        }
        ArrivalKind::TraceReplay {
            times: trace,
            scale,
            loop_around,
            ..
        } => {
            // Replay verbatim; `scale` multiplies the rate (divides
            // time); loop-around tiles with period = last timestamp.
            let span = trace.last().copied().unwrap_or(0.0);
            let mut offset = 0.0;
            loop {
                let mut any_in = false;
                for &ts in trace {
                    let at = (ts + offset) / scale;
                    if at < scenario.duration {
                        times.push(at);
                        any_in = true;
                    }
                }
                if !*loop_around || span <= 0.0 || !any_in {
                    break;
                }
                offset += span;
            }
        }
    }
    times.sort_by(f64::total_cmp);
    times
}

/// Pre-materialised arrival stream for a scenario (sorted by time).
///
/// Materialising up front keeps the DES hot loop allocation-free and makes
/// paired comparisons (LA-IMR vs baseline on *identical* arrivals) exact —
/// the variance-reduction trick behind Table VI.
#[derive(Debug)]
pub struct ArrivalGenerator {
    arrivals: Vec<Arrival>,
}

impl ArrivalGenerator {
    /// Generate the full stream for `scenario`.
    pub fn generate(scenario: &ScenarioConfig) -> Self {
        let mut rng = Rng::new(scenario.seed);
        let times = materialise_times(scenario, &mut rng);

        // Assign quality classes by the scenario mix, deterministically
        // from the same seed stream.
        let mix = scenario.mix();
        let arrivals = times
            .into_iter()
            .map(|at| Arrival {
                at,
                quality: classify(rng.uniform(), mix),
            })
            .collect();
        ArrivalGenerator { arrivals }
    }

    pub fn arrivals(&self) -> &[Arrival] {
        &self.arrivals
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Empirical mean rate of the generated stream [req/s].
    pub fn empirical_rate(&self, duration: f64) -> f64 {
        if duration <= 0.0 {
            return 0.0;
        }
        self.arrivals.len() as f64 / duration
    }

    /// Peak 1-second-window rate — burstiness diagnostic.
    pub fn peak_rate(&self) -> f64 {
        let mut peak = 0usize;
        let mut lo = 0usize;
        for hi in 0..self.arrivals.len() {
            while self.arrivals[hi].at - self.arrivals[lo].at > 1.0 {
                lo += 1;
            }
            peak = peak.max(hi - lo + 1);
        }
        peak as f64
    }
}

/// Per-kind resumable sampler state for the chunk streamer.
#[derive(Debug)]
enum KindState {
    Poisson {
        lambda: f64,
        t: f64,
        pending: Option<f64>,
    },
    Periodic {
        period: f64,
        t: f64,
    },
    Bursts {
        burst_rate: f64,
        alpha: f64,
        lo: f64,
        hi: f64,
        intra_gap: f64,
        /// Base (burst-start) process clock.
        t: f64,
        src_done: bool,
        /// Materialised members not yet emitted: (time, generation seq).
        /// Bounded by burst overlap, never by the total request count.
        pending: Vec<(f64, u64)>,
        gen_seq: u64,
    },
    Diurnal {
        base: f64,
        amplitude: f64,
        period: f64,
        phase: f64,
        peak: f64,
        t: f64,
        pending: Option<f64>,
    },
    Mmpp {
        rates: Vec<f64>,
        dwell: Vec<f64>,
        s: usize,
        t: f64,
        seg_end: f64,
        a: f64,
        in_segment: bool,
        pending: Option<f64>,
    },
    Trace {
        trace: Vec<f64>,
        scale: f64,
        loop_around: bool,
        span: f64,
        offset: f64,
        pos: usize,
        any_in: bool,
    },
    /// Fallback for kinds whose canonical draw order cannot be banded by
    /// time (step profiles draw segment-by-segment; an unsorted replay
    /// trace emits out of order): materialise once, stream by index.
    Eager {
        times: Vec<f64>,
        pos: usize,
    },
    Done,
}

/// Push every stream time in `[.., chunk_end)` into `out` (ascending,
/// generation order on ties — matching the materialised stable sort),
/// consuming `rng` in the canonical order. Returns true once the source
/// is fully exhausted (nothing pending either).
fn fill(
    state: &mut KindState,
    rng: &mut Rng,
    duration: f64,
    chunk_end: f64,
    out: &mut Vec<SimTime>,
) -> bool {
    match state {
        KindState::Done => true,
        KindState::Eager { times, pos } => {
            while *pos < times.len() && times[*pos] < chunk_end {
                out.push(times[*pos]);
                *pos += 1;
            }
            *pos >= times.len()
        }
        KindState::Poisson { lambda, t, pending } => {
            if let Some(p) = *pending {
                if p < chunk_end {
                    out.push(p);
                    *pending = None;
                } else {
                    return false;
                }
            }
            loop {
                *t += rng.exp(*lambda);
                if *t >= duration {
                    return true;
                }
                if *t < chunk_end {
                    out.push(*t);
                } else {
                    *pending = Some(*t);
                    return false;
                }
            }
        }
        KindState::Periodic { period, t } => {
            while *t < duration && *t < chunk_end {
                out.push(*t);
                *t += *period;
            }
            *t >= duration
        }
        KindState::Bursts {
            burst_rate,
            alpha,
            lo,
            hi,
            intra_gap,
            t,
            src_done,
            pending,
            gen_seq,
        } => {
            // Advance the base process until every burst that could
            // start before `chunk_end` has materialised its members
            // (members only extend *forward* from the burst start, so
            // once the base clock passes the boundary the chunk is
            // closed). Time and size draws stay interleaved exactly as
            // in the materialised path.
            while !*src_done && *t < chunk_end {
                *t += rng.exp(*burst_rate);
                if *t >= duration {
                    *src_done = true;
                    break;
                }
                let size = rng.bounded_pareto(*alpha, *lo, *hi).round() as usize;
                for k in 0..size.max(1) {
                    let at = *t + k as f64 * *intra_gap;
                    if at < duration {
                        pending.push((at, *gen_seq));
                        *gen_seq += 1;
                    }
                }
            }
            let mut due: Vec<(f64, u64)> = Vec::new();
            pending.retain(|&(at, gs)| {
                if at < chunk_end {
                    due.push((at, gs));
                    false
                } else {
                    true
                }
            });
            // (time, generation order) == the stable sort of the
            // materialised member list.
            due.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
            out.extend(due.iter().map(|d| d.0));
            *src_done && pending.is_empty()
        }
        KindState::Diurnal {
            base,
            amplitude,
            period,
            phase,
            peak,
            t,
            pending,
        } => {
            if let Some(p) = *pending {
                if p < chunk_end {
                    out.push(p);
                    *pending = None;
                } else {
                    return false;
                }
            }
            let two_pi = 2.0 * std::f64::consts::PI;
            loop {
                *t += rng.exp(*peak);
                if *t >= duration {
                    return true;
                }
                let rate = *base * (1.0 + *amplitude * (two_pi * *t / *period + *phase).sin());
                if rng.uniform() * *peak < rate {
                    if *t < chunk_end {
                        out.push(*t);
                    } else {
                        *pending = Some(*t);
                        return false;
                    }
                }
            }
        }
        KindState::Mmpp {
            rates,
            dwell,
            s,
            t,
            seg_end,
            a,
            in_segment,
            pending,
        } => {
            if let Some(p) = *pending {
                if p < chunk_end {
                    out.push(p);
                    *pending = None;
                } else {
                    return false;
                }
            }
            loop {
                if !*in_segment {
                    if *t >= duration {
                        return true;
                    }
                    *seg_end = (*t + rng.exp(1.0 / dwell[*s])).min(duration);
                    *a = *t;
                    *in_segment = true;
                }
                if rates[*s] > 0.0 {
                    loop {
                        *a += rng.exp(rates[*s]);
                        if *a >= *seg_end {
                            break;
                        }
                        if *a < chunk_end {
                            out.push(*a);
                        } else {
                            *pending = Some(*a);
                            return false;
                        }
                    }
                }
                *t = *seg_end;
                *in_segment = false;
                if rates.len() > 1 {
                    let mut next = rng.below(rates.len() - 1);
                    if next >= *s {
                        next += 1;
                    }
                    *s = next;
                }
            }
        }
        KindState::Trace {
            trace,
            scale,
            loop_around,
            span,
            offset,
            pos,
            any_in,
        } => {
            if trace.is_empty() {
                return true;
            }
            loop {
                if *pos >= trace.len() {
                    if !*loop_around || *span <= 0.0 || !*any_in {
                        return true;
                    }
                    *offset += *span;
                    *pos = 0;
                    *any_in = false;
                }
                let at = (trace[*pos] + *offset) / *scale;
                if at >= duration {
                    *pos += 1;
                    continue;
                }
                if at < chunk_end {
                    out.push(at);
                    *any_in = true;
                    *pos += 1;
                } else {
                    return false;
                }
            }
        }
    }
}

/// Chunk-streamed arrival generation: the same stream as
/// [`ArrivalGenerator::generate`], emitted in `[k·span, (k+1)·span)`
/// time bands so peak memory is O(rate × span) instead of O(total).
#[derive(Debug)]
pub struct ArrivalStream {
    state: KindState,
    rng: Rng,
    qrng: Rng,
    mix: [f64; 3],
    duration: f64,
    span: f64,
    loaded_until: f64,
    scratch: Vec<SimTime>,
    buf: Vec<Arrival>,
    emitted: u64,
    done: bool,
}

impl ArrivalStream {
    /// `chunk_span` is the time band per refill — callers tie it to the
    /// event queue's ladder-epoch span so refills land on epoch
    /// boundaries. The chunk buffer is presized from the scenario's
    /// analytic mean-rate envelope.
    pub fn new(scenario: &ScenarioConfig, chunk_span: f64) -> Self {
        let mut rng = Rng::new(scenario.seed);
        let qrng = Rng::new(scenario.seed ^ QUALITY_SALT);
        let state = match &scenario.arrivals {
            ArrivalKind::Poisson { lambda } => {
                if *lambda > 0.0 {
                    KindState::Poisson {
                        lambda: *lambda,
                        t: 0.0,
                        pending: None,
                    }
                } else {
                    KindState::Done
                }
            }
            ArrivalKind::Periodic { rate } => {
                if *rate > 0.0 {
                    KindState::Periodic {
                        period: 1.0 / rate,
                        t: 1.0 / rate,
                    }
                } else {
                    KindState::Done
                }
            }
            ArrivalKind::BoundedParetoBursts {
                burst_rate,
                alpha,
                lo,
                hi,
                intra_gap,
            } => {
                if *burst_rate > 0.0 {
                    KindState::Bursts {
                        burst_rate: *burst_rate,
                        alpha: *alpha,
                        lo: *lo,
                        hi: *hi,
                        intra_gap: *intra_gap,
                        t: 0.0,
                        src_done: false,
                        pending: Vec::new(),
                        gen_seq: 0,
                    }
                } else {
                    KindState::Done
                }
            }
            ArrivalKind::Steps { .. } => KindState::Eager {
                times: materialise_times(scenario, &mut rng),
                pos: 0,
            },
            ArrivalKind::Diurnal {
                base,
                amplitude,
                period,
                phase,
            } => {
                let peak = base * (1.0 + amplitude);
                if peak > 0.0 {
                    KindState::Diurnal {
                        base: *base,
                        amplitude: *amplitude,
                        period: *period,
                        phase: *phase,
                        peak,
                        t: 0.0,
                        pending: None,
                    }
                } else {
                    KindState::Done
                }
            }
            ArrivalKind::Mmpp { rates, dwell } => {
                if rates.is_empty() {
                    KindState::Done
                } else {
                    KindState::Mmpp {
                        rates: rates.clone(),
                        dwell: dwell.clone(),
                        s: 0,
                        t: 0.0,
                        seg_end: 0.0,
                        a: 0.0,
                        in_segment: false,
                        pending: None,
                    }
                }
            }
            ArrivalKind::TraceReplay {
                times: trace,
                scale,
                loop_around,
                ..
            } => {
                if trace.windows(2).any(|w| w[0] > w[1]) {
                    // Unsorted trace: generation order != time order, so
                    // banding would scramble the stable sort. Rare and
                    // bounded by the trace file size.
                    KindState::Eager {
                        times: materialise_times(scenario, &mut rng),
                        pos: 0,
                    }
                } else {
                    KindState::Trace {
                        trace: trace.clone(),
                        scale: *scale,
                        loop_around: *loop_around,
                        span: trace.last().copied().unwrap_or(0.0),
                        offset: 0.0,
                        pos: 0,
                        any_in: false,
                    }
                }
            }
        };
        let span = if chunk_span.is_finite() && chunk_span > 1e-3 {
            chunk_span
        } else {
            16.0
        };
        // Presize from the analytic rate envelope (satellite: capacity
        // hints so chunk emission never regrows in the steady state).
        let cap = (scenario.mean_rate() * span * 1.3).ceil() as usize + 8;
        let done = matches!(state, KindState::Done);
        ArrivalStream {
            state,
            rng,
            qrng,
            mix: scenario.mix(),
            duration: scenario.duration,
            span,
            loaded_until: 0.0,
            scratch: Vec::with_capacity(cap),
            buf: Vec::with_capacity(cap),
            emitted: 0,
            done,
        }
    }

    /// All arrivals so far are strictly before this time; the next chunk
    /// starts here.
    pub fn loaded_until(&self) -> f64 {
        self.loaded_until
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Total arrivals emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Emit the next time band. The slice is valid until the next call.
    pub fn next_chunk(&mut self) -> &[Arrival] {
        self.buf.clear();
        if self.done {
            return &self.buf;
        }
        self.scratch.clear();
        let mut chunk_end = self.loaded_until + self.span;
        if chunk_end >= self.duration {
            // Final band: drain everything (all kinds terminate at the
            // duration horizon).
            chunk_end = f64::INFINITY;
        }
        let finished = fill(
            &mut self.state,
            &mut self.rng,
            self.duration,
            chunk_end,
            &mut self.scratch,
        );
        for &at in &self.scratch {
            let quality = classify(self.qrng.uniform(), self.mix);
            self.buf.push(Arrival { at, quality });
        }
        self.emitted += self.buf.len() as u64;
        self.loaded_until = chunk_end;
        if finished || chunk_end.is_infinite() {
            self.done = true;
            self.loaded_until = f64::INFINITY;
        }
        &self.buf
    }

    /// Drain the whole stream (tests / small scenarios).
    pub fn collect_all(mut self) -> Vec<Arrival> {
        let mut all = Vec::new();
        while !self.is_done() {
            all.extend_from_slice(self.next_chunk());
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioConfig;

    #[test]
    fn poisson_rate_matches() {
        let s = ScenarioConfig::poisson(4.0, 7).with_duration(500.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let rate = g.empirical_rate(500.0);
        assert!((rate - 4.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = ScenarioConfig::poisson(3.0, 9);
        let a = ArrivalGenerator::generate(&s);
        let b = ArrivalGenerator::generate(&s);
        assert_eq!(a.arrivals(), b.arrivals());
        let c = ArrivalGenerator::generate(&ScenarioConfig::poisson(3.0, 10));
        assert_ne!(a.arrivals(), c.arrivals());
    }

    #[test]
    fn sorted_and_within_duration() {
        let s = ScenarioConfig::bursty(4.0, 3).with_duration(120.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let arr = g.arrivals();
        assert!(arr.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(arr.iter().all(|a| a.at < 120.0));
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        let sp = ScenarioConfig::poisson(4.0, 5).with_duration(300.0, 0.0);
        let sb = ScenarioConfig::bursty(4.0, 5).with_duration(300.0, 0.0);
        let p = ArrivalGenerator::generate(&sp);
        let b = ArrivalGenerator::generate(&sb);
        assert!(
            b.peak_rate() > p.peak_rate(),
            "bursty peak {} !> poisson peak {}",
            b.peak_rate(),
            p.peak_rate()
        );
    }

    #[test]
    fn quality_mix_respected() {
        let mut s = ScenarioConfig::poisson(10.0, 21).with_duration(300.0, 0.0);
        s.quality_mix = [0.5, 0.5, 0.0];
        let g = ArrivalGenerator::generate(&s);
        let n = g.len() as f64;
        let low = g
            .arrivals()
            .iter()
            .filter(|a| a.quality == QualityClass::LowLatency)
            .count() as f64;
        assert!((low / n - 0.5).abs() < 0.05, "low share={}", low / n);
        assert!(g
            .arrivals()
            .iter()
            .all(|a| a.quality != QualityClass::Precise));
    }

    #[test]
    fn periodic_exact_count() {
        let s = ScenarioConfig {
            arrivals: ArrivalKind::Periodic { rate: 2.0 },
            duration: 10.0,
            ..ScenarioConfig::default()
        };
        let g = ArrivalGenerator::generate(&s);
        // t = 0.5, 1.0, ..., 9.5 → 19 arrivals.
        assert_eq!(g.len(), 19);
    }

    #[test]
    fn steps_change_rate() {
        let s = ScenarioConfig {
            arrivals: ArrivalKind::Steps {
                steps: vec![(0.0, 1.0), (100.0, 8.0)],
            },
            duration: 200.0,
            warmup: 0.0,
            ..ScenarioConfig::default()
        };
        let g = ArrivalGenerator::generate(&s);
        let first: usize = g.arrivals().iter().filter(|a| a.at < 100.0).count();
        let second = g.len() - first;
        assert!(second > 4 * first, "first={first} second={second}");
    }

    #[test]
    fn zero_rate_empty() {
        let s = ScenarioConfig::poisson(0.0, 1);
        assert!(ArrivalGenerator::generate(&s).is_empty());
    }

    #[test]
    fn diurnal_peak_outweighs_trough() {
        // Amplitude 0.8, period 120, phase 0: peak quarter is centred on
        // t ≡ 30 (mod 120), trough on t ≡ 90. Bin arrivals by phase.
        let s = ScenarioConfig::diurnal(4.0, 13).with_duration(600.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let (mut peak, mut trough) = (0usize, 0usize);
        for a in g.arrivals() {
            let ph = a.at % 120.0;
            if (15.0..45.0).contains(&ph) {
                peak += 1;
            } else if (75.0..105.0).contains(&ph) {
                trough += 1;
            }
        }
        assert!(
            peak > 2 * trough.max(1),
            "peak {peak} !>> trough {trough}"
        );
    }

    #[test]
    fn mmpp_burstier_than_poisson_same_mean() {
        let sp = ScenarioConfig::poisson(4.0, 5).with_duration(600.0, 0.0);
        let sm = ScenarioConfig::mmpp_bursts(4.0, 5).with_duration(600.0, 0.0);
        let p = ArrivalGenerator::generate(&sp);
        let m = ArrivalGenerator::generate(&sm);
        assert!(
            m.peak_rate() > p.peak_rate(),
            "mmpp peak {} !> poisson peak {}",
            m.peak_rate(),
            p.peak_rate()
        );
        // Mean still near the target.
        assert!(
            (m.empirical_rate(600.0) - 4.0).abs() < 1.2,
            "mmpp rate={}",
            m.empirical_rate(600.0)
        );
    }

    #[test]
    fn trace_replay_identity_at_scale_one() {
        let trace: Vec<f64> = (0..40).map(|k| 0.5 + k as f64 * 0.7).collect();
        let s = ScenarioConfig::trace_replay("t", trace.clone(), 3).with_duration(100.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let replayed: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(replayed, trace, "scale=1 must replay the trace verbatim");
    }

    #[test]
    fn trace_replay_scales_and_loops() {
        use crate::config::ArrivalKind;
        let mut s = ScenarioConfig::trace_replay("t", vec![1.0, 2.0, 4.0], 3)
            .with_duration(6.0, 0.0);
        // Scale 2 halves the timestamps.
        if let ArrivalKind::TraceReplay { scale, .. } = &mut s.arrivals {
            *scale = 2.0;
        }
        let g = ArrivalGenerator::generate(&s);
        let at: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(at, vec![0.5, 1.0, 2.0]);

        // Loop-around tiles with period = last timestamp (4 s).
        let mut s = ScenarioConfig::trace_replay("t", vec![1.0, 2.0, 4.0], 3)
            .with_duration(9.0, 0.0);
        if let ArrivalKind::TraceReplay { loop_around, .. } = &mut s.arrivals {
            *loop_around = true;
        }
        let g = ArrivalGenerator::generate(&s);
        let at: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(at, vec![1.0, 2.0, 4.0, 5.0, 6.0, 8.0]);
    }

    // ---- chunk streamer: differential against the materialised oracle ----

    fn stream_kinds() -> Vec<ScenarioConfig> {
        let mut trace_loop = ScenarioConfig::trace_replay("t", vec![1.0, 2.0, 4.0], 3)
            .with_duration(9.0, 0.0);
        if let ArrivalKind::TraceReplay { loop_around, .. } = &mut trace_loop.arrivals {
            *loop_around = true;
        }
        vec![
            ScenarioConfig::poisson(4.0, 7).with_duration(200.0, 0.0),
            ScenarioConfig::bursty(4.0, 3).with_duration(200.0, 0.0),
            ScenarioConfig::diurnal(4.0, 13).with_duration(300.0, 0.0),
            ScenarioConfig::mmpp_bursts(4.0, 5).with_duration(300.0, 0.0),
            ScenarioConfig {
                arrivals: ArrivalKind::Periodic { rate: 2.0 },
                duration: 50.0,
                ..ScenarioConfig::default()
            },
            ScenarioConfig {
                arrivals: ArrivalKind::Steps {
                    steps: vec![(0.0, 1.0), (60.0, 8.0)],
                },
                duration: 120.0,
                warmup: 0.0,
                ..ScenarioConfig::default()
            },
            trace_loop,
        ]
    }

    #[test]
    fn stream_times_match_materialised_for_every_kind() {
        // The chunked stream must reproduce the materialised oracle's
        // time sequence *exactly* (same RNG draw order), for every
        // arrival kind. With the default [0,1,0] quality mix the full
        // Arrival sequence matches too — the property that keeps
        // `engine.mode = des` bit-identical after the streaming swap.
        for s in stream_kinds() {
            let oracle = ArrivalGenerator::generate(&s);
            let streamed = ArrivalStream::new(&s, 7.0).collect_all();
            assert_eq!(
                streamed.len(),
                oracle.len(),
                "count diverged for {:?}",
                s.arrivals
            );
            for (i, (a, b)) in streamed.iter().zip(oracle.arrivals()).enumerate() {
                assert_eq!(a.at.to_bits(), b.at.to_bits(), "time {i} diverged");
                assert_eq!(a.quality, b.quality, "quality {i} diverged (default mix)");
            }
        }
    }

    #[test]
    fn stream_is_chunk_span_invariant() {
        // The chunk span is a memory knob, not a behavioural one.
        for s in stream_kinds() {
            let a = ArrivalStream::new(&s, 3.0).collect_all();
            let b = ArrivalStream::new(&s, 17.0).collect_all();
            let c = ArrivalStream::new(&s, 1.0e6).collect_all();
            assert_eq!(a, b, "span 3 vs 17 diverged for {:?}", s.arrivals);
            assert_eq!(a, c, "span 3 vs one-shot diverged for {:?}", s.arrivals);
        }
    }

    #[test]
    fn stream_chunks_are_time_banded() {
        // Every chunk stays within [previous loaded_until, chunk_end):
        // the engine relies on this to bound how much of the stream can
        // be in the event queue at once.
        let s = ScenarioConfig::bursty(6.0, 11).with_duration(150.0, 0.0);
        let mut stream = ArrivalStream::new(&s, 5.0);
        let mut lo = 0.0f64;
        let mut total = 0usize;
        while !stream.is_done() {
            let hi = stream.loaded_until() + 5.0;
            let chunk = stream.next_chunk();
            assert!(
                chunk.iter().all(|a| a.at >= lo && (a.at < hi || hi.is_nan())),
                "chunk escaped its band [{lo}, {hi})"
            );
            assert!(chunk.windows(2).all(|w| w[0].at <= w[1].at));
            total += chunk.len();
            lo = if stream.loaded_until().is_finite() {
                stream.loaded_until()
            } else {
                lo
            };
        }
        assert_eq!(total as u64, stream.emitted());
        assert_eq!(total, ArrivalGenerator::generate(&s).len());
    }

    #[test]
    fn stream_salted_quality_mix_respected() {
        // The streaming front end draws qualities from the salted
        // stream; the configured mix must still hold statistically.
        let mut s = ScenarioConfig::poisson(10.0, 21).with_duration(300.0, 0.0);
        s.quality_mix = [0.5, 0.5, 0.0];
        let all = ArrivalStream::new(&s, 11.0).collect_all();
        let n = all.len() as f64;
        let low = all
            .iter()
            .filter(|a| a.quality == QualityClass::LowLatency)
            .count() as f64;
        assert!((low / n - 0.5).abs() < 0.05, "low share={}", low / n);
        assert!(all.iter().all(|a| a.quality != QualityClass::Precise));
    }

    #[test]
    fn stream_zero_rate_terminates_empty() {
        let s = ScenarioConfig::poisson(0.0, 1);
        let mut stream = ArrivalStream::new(&s, 4.0);
        assert!(stream.is_done());
        assert!(stream.next_chunk().is_empty());
    }
}
