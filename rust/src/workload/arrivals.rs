//! Arrival-process generators for every `ArrivalKind` in the scenario
//! config: Poisson, bounded-Pareto burst trains (paper §V-D), periodic,
//! step profiles, diurnal (sinusoidal-envelope) profiles, regime-
//! switching MMPP bursts, and deterministic trace replay.

use crate::config::{ArrivalKind, QualityClass, ScenarioConfig};
use crate::rng::Rng;
use crate::SimTime;

/// One generated request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub at: SimTime,
    pub quality: QualityClass,
}

/// Pre-materialised arrival stream for a scenario (sorted by time).
///
/// Materialising up front keeps the DES hot loop allocation-free and makes
/// paired comparisons (LA-IMR vs baseline on *identical* arrivals) exact —
/// the variance-reduction trick behind Table VI.
#[derive(Debug)]
pub struct ArrivalGenerator {
    arrivals: Vec<Arrival>,
}

impl ArrivalGenerator {
    /// Generate the full stream for `scenario`.
    pub fn generate(scenario: &ScenarioConfig) -> Self {
        let mut rng = Rng::new(scenario.seed);
        let mut times: Vec<SimTime> = Vec::new();
        match &scenario.arrivals {
            ArrivalKind::Poisson { lambda } => {
                let mut t = 0.0;
                if *lambda > 0.0 {
                    loop {
                        t += rng.exp(*lambda);
                        if t >= scenario.duration {
                            break;
                        }
                        times.push(t);
                    }
                }
            }
            ArrivalKind::Periodic { rate } => {
                if *rate > 0.0 {
                    let period = 1.0 / rate;
                    let mut t = period;
                    while t < scenario.duration {
                        times.push(t);
                        t += period;
                    }
                }
            }
            ArrivalKind::BoundedParetoBursts {
                burst_rate,
                alpha,
                lo,
                hi,
                intra_gap,
            } => {
                let mut t = 0.0;
                if *burst_rate > 0.0 {
                    loop {
                        t += rng.exp(*burst_rate);
                        if t >= scenario.duration {
                            break;
                        }
                        let size = rng.bounded_pareto(*alpha, *lo, *hi).round() as usize;
                        for k in 0..size.max(1) {
                            let at = t + k as f64 * intra_gap;
                            if at < scenario.duration {
                                times.push(at);
                            }
                        }
                    }
                }
            }
            ArrivalKind::Steps { steps } => {
                for (idx, &(start, rate)) in steps.iter().enumerate() {
                    let end = steps
                        .get(idx + 1)
                        .map(|s| s.0)
                        .unwrap_or(scenario.duration)
                        .min(scenario.duration);
                    if rate <= 0.0 {
                        continue;
                    }
                    let mut t = start;
                    loop {
                        t += rng.exp(rate);
                        if t >= end {
                            break;
                        }
                        times.push(t);
                    }
                }
            }
            ArrivalKind::Diurnal {
                base,
                amplitude,
                period,
                phase,
            } => {
                // Thinning (Lewis–Shedler): draw a homogeneous Poisson at
                // the peak rate, accept each point with probability
                // λ(t)/peak — an *exact* non-homogeneous Poisson sample.
                let peak = base * (1.0 + amplitude);
                if peak > 0.0 {
                    let two_pi = 2.0 * std::f64::consts::PI;
                    let mut t = 0.0;
                    loop {
                        t += rng.exp(peak);
                        if t >= scenario.duration {
                            break;
                        }
                        let rate = base * (1.0 + amplitude * (two_pi * t / period + phase).sin());
                        if rng.uniform() * peak < rate {
                            times.push(t);
                        }
                    }
                }
            }
            ArrivalKind::Mmpp { rates, dwell } => {
                if !rates.is_empty() {
                    let mut s = 0usize;
                    let mut t = 0.0;
                    while t < scenario.duration {
                        let seg_end = (t + rng.exp(1.0 / dwell[s])).min(scenario.duration);
                        if rates[s] > 0.0 {
                            let mut a = t;
                            loop {
                                a += rng.exp(rates[s]);
                                if a >= seg_end {
                                    break;
                                }
                                times.push(a);
                            }
                        }
                        t = seg_end;
                        // Jump uniformly to one of the *other* regimes
                        // (alternation when there are two).
                        if rates.len() > 1 {
                            let mut next = rng.below(rates.len() - 1);
                            if next >= s {
                                next += 1;
                            }
                            s = next;
                        }
                    }
                }
            }
            ArrivalKind::TraceReplay {
                times: trace,
                scale,
                loop_around,
                ..
            } => {
                // Replay verbatim; `scale` multiplies the rate (divides
                // time); loop-around tiles with period = last timestamp.
                let span = trace.last().copied().unwrap_or(0.0);
                let mut offset = 0.0;
                loop {
                    let mut any_in = false;
                    for &ts in trace {
                        let at = (ts + offset) / scale;
                        if at < scenario.duration {
                            times.push(at);
                            any_in = true;
                        }
                    }
                    if !*loop_around || span <= 0.0 || !any_in {
                        break;
                    }
                    offset += span;
                }
            }
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());

        // Assign quality classes by the scenario mix, deterministically
        // from the same seed stream.
        let mix = scenario.mix();
        let arrivals = times
            .into_iter()
            .map(|at| {
                let u = rng.uniform();
                let quality = if u < mix[0] {
                    QualityClass::LowLatency
                } else if u < mix[0] + mix[1] {
                    QualityClass::Balanced
                } else {
                    QualityClass::Precise
                };
                Arrival { at, quality }
            })
            .collect();
        ArrivalGenerator { arrivals }
    }

    pub fn arrivals(&self) -> &[Arrival] {
        &self.arrivals
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Empirical mean rate of the generated stream [req/s].
    pub fn empirical_rate(&self, duration: f64) -> f64 {
        if duration <= 0.0 {
            return 0.0;
        }
        self.arrivals.len() as f64 / duration
    }

    /// Peak 1-second-window rate — burstiness diagnostic.
    pub fn peak_rate(&self) -> f64 {
        let mut peak = 0usize;
        let mut lo = 0usize;
        for hi in 0..self.arrivals.len() {
            while self.arrivals[hi].at - self.arrivals[lo].at > 1.0 {
                lo += 1;
            }
            peak = peak.max(hi - lo + 1);
        }
        peak as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioConfig;

    #[test]
    fn poisson_rate_matches() {
        let s = ScenarioConfig::poisson(4.0, 7).with_duration(500.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let rate = g.empirical_rate(500.0);
        assert!((rate - 4.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = ScenarioConfig::poisson(3.0, 9);
        let a = ArrivalGenerator::generate(&s);
        let b = ArrivalGenerator::generate(&s);
        assert_eq!(a.arrivals(), b.arrivals());
        let c = ArrivalGenerator::generate(&ScenarioConfig::poisson(3.0, 10));
        assert_ne!(a.arrivals(), c.arrivals());
    }

    #[test]
    fn sorted_and_within_duration() {
        let s = ScenarioConfig::bursty(4.0, 3).with_duration(120.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let arr = g.arrivals();
        assert!(arr.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(arr.iter().all(|a| a.at < 120.0));
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        let sp = ScenarioConfig::poisson(4.0, 5).with_duration(300.0, 0.0);
        let sb = ScenarioConfig::bursty(4.0, 5).with_duration(300.0, 0.0);
        let p = ArrivalGenerator::generate(&sp);
        let b = ArrivalGenerator::generate(&sb);
        assert!(
            b.peak_rate() > p.peak_rate(),
            "bursty peak {} !> poisson peak {}",
            b.peak_rate(),
            p.peak_rate()
        );
    }

    #[test]
    fn quality_mix_respected() {
        let mut s = ScenarioConfig::poisson(10.0, 21).with_duration(300.0, 0.0);
        s.quality_mix = [0.5, 0.5, 0.0];
        let g = ArrivalGenerator::generate(&s);
        let n = g.len() as f64;
        let low = g
            .arrivals()
            .iter()
            .filter(|a| a.quality == QualityClass::LowLatency)
            .count() as f64;
        assert!((low / n - 0.5).abs() < 0.05, "low share={}", low / n);
        assert!(g
            .arrivals()
            .iter()
            .all(|a| a.quality != QualityClass::Precise));
    }

    #[test]
    fn periodic_exact_count() {
        let s = ScenarioConfig {
            arrivals: ArrivalKind::Periodic { rate: 2.0 },
            duration: 10.0,
            ..ScenarioConfig::default()
        };
        let g = ArrivalGenerator::generate(&s);
        // t = 0.5, 1.0, ..., 9.5 → 19 arrivals.
        assert_eq!(g.len(), 19);
    }

    #[test]
    fn steps_change_rate() {
        let s = ScenarioConfig {
            arrivals: ArrivalKind::Steps {
                steps: vec![(0.0, 1.0), (100.0, 8.0)],
            },
            duration: 200.0,
            warmup: 0.0,
            ..ScenarioConfig::default()
        };
        let g = ArrivalGenerator::generate(&s);
        let first: usize = g.arrivals().iter().filter(|a| a.at < 100.0).count();
        let second = g.len() - first;
        assert!(second > 4 * first, "first={first} second={second}");
    }

    #[test]
    fn zero_rate_empty() {
        let s = ScenarioConfig::poisson(0.0, 1);
        assert!(ArrivalGenerator::generate(&s).is_empty());
    }

    #[test]
    fn diurnal_peak_outweighs_trough() {
        // Amplitude 0.8, period 120, phase 0: peak quarter is centred on
        // t ≡ 30 (mod 120), trough on t ≡ 90. Bin arrivals by phase.
        let s = ScenarioConfig::diurnal(4.0, 13).with_duration(600.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let (mut peak, mut trough) = (0usize, 0usize);
        for a in g.arrivals() {
            let ph = a.at % 120.0;
            if (15.0..45.0).contains(&ph) {
                peak += 1;
            } else if (75.0..105.0).contains(&ph) {
                trough += 1;
            }
        }
        assert!(
            peak > 2 * trough.max(1),
            "peak {peak} !>> trough {trough}"
        );
    }

    #[test]
    fn mmpp_burstier_than_poisson_same_mean() {
        let sp = ScenarioConfig::poisson(4.0, 5).with_duration(600.0, 0.0);
        let sm = ScenarioConfig::mmpp_bursts(4.0, 5).with_duration(600.0, 0.0);
        let p = ArrivalGenerator::generate(&sp);
        let m = ArrivalGenerator::generate(&sm);
        assert!(
            m.peak_rate() > p.peak_rate(),
            "mmpp peak {} !> poisson peak {}",
            m.peak_rate(),
            p.peak_rate()
        );
        // Mean still near the target.
        assert!(
            (m.empirical_rate(600.0) - 4.0).abs() < 1.2,
            "mmpp rate={}",
            m.empirical_rate(600.0)
        );
    }

    #[test]
    fn trace_replay_identity_at_scale_one() {
        let trace: Vec<f64> = (0..40).map(|k| 0.5 + k as f64 * 0.7).collect();
        let s = ScenarioConfig::trace_replay("t", trace.clone(), 3).with_duration(100.0, 0.0);
        let g = ArrivalGenerator::generate(&s);
        let replayed: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(replayed, trace, "scale=1 must replay the trace verbatim");
    }

    #[test]
    fn trace_replay_scales_and_loops() {
        use crate::config::ArrivalKind;
        let mut s = ScenarioConfig::trace_replay("t", vec![1.0, 2.0, 4.0], 3)
            .with_duration(6.0, 0.0);
        // Scale 2 halves the timestamps.
        if let ArrivalKind::TraceReplay { scale, .. } = &mut s.arrivals {
            *scale = 2.0;
        }
        let g = ArrivalGenerator::generate(&s);
        let at: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(at, vec![0.5, 1.0, 2.0]);

        // Loop-around tiles with period = last timestamp (4 s).
        let mut s = ScenarioConfig::trace_replay("t", vec![1.0, 2.0, 4.0], 3)
            .with_duration(9.0, 0.0);
        if let ArrivalKind::TraceReplay { loop_around, .. } = &mut s.arrivals {
            *loop_around = true;
        }
        let g = ArrivalGenerator::generate(&s);
        let at: Vec<f64> = g.arrivals().iter().map(|a| a.at).collect();
        assert_eq!(at, vec![1.0, 2.0, 4.0, 5.0, 6.0, 8.0]);
    }
}
