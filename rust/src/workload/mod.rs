//! Workload generation: arrival processes and the robot-fleet client
//! model standing in for the CloudGripper testbed (see DESIGN.md §3 —
//! the router never inspects pixels, so the arrival process + payload
//! shape are the faithful substitution).

mod arrivals;
mod robots;

pub use arrivals::{Arrival, ArrivalGenerator, ArrivalStream};
pub use robots::{Robot, RobotFleet};
