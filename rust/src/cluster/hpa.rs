//! Horizontal-Pod-Autoscaler reconcile loop (§IV-D).
//!
//! Every `interval` (paper: 5 s) the controller reads the
//! `desired_replicas` custom metric for each Deployment — surfaced through
//! the metric registry as by k8s-prometheus-adapter — and scales by the
//! exact difference, bounded by per-Deployment caps. The HPA itself is
//! policy-free: *what* number to publish is the autoscaler's job
//! (`autoscaler::{PmHpa, ReactiveBaseline}`).

use super::deployment::Deployment;
use super::metrics::{MetricRegistry, DESIRED_REPLICAS};
use crate::SimTime;

/// Reconciling controller for a set of deployments.
#[derive(Debug)]
pub struct HpaController {
    interval: f64,
    last_run: SimTime,
}

impl HpaController {
    pub fn new(interval: f64) -> Self {
        Self {
            interval,
            last_run: f64::NEG_INFINITY,
        }
    }

    pub fn interval(&self) -> f64 {
        self.interval
    }

    /// Is a reconcile due at `now`?
    pub fn due(&self, now: SimTime) -> bool {
        now - self.last_run >= self.interval
    }

    /// Run one reconcile pass: for each deployment read the custom metric
    /// and actuate the difference. Returns (scoped metric name, delta) for
    /// every deployment that changed.
    pub fn reconcile(
        &mut self,
        deployments: &mut [Deployment],
        metrics: &MetricRegistry,
        now: SimTime,
    ) -> Vec<(String, i64)> {
        let mut refs: Vec<&mut Deployment> = deployments.iter_mut().collect();
        self.reconcile_refs(&mut refs, metrics, now)
    }

    /// Reconcile over a slice of deployment references (for callers whose
    /// deployments live inside larger runtime structs).
    pub fn reconcile_refs(
        &mut self,
        deployments: &mut [&mut Deployment],
        metrics: &MetricRegistry,
        now: SimTime,
    ) -> Vec<(String, i64)> {
        self.last_run = now;
        let mut changes = Vec::new();
        for d in deployments.iter_mut() {
            let name = MetricRegistry::scoped(DESIRED_REPLICAS, d.key.model, d.key.instance);
            // The custom-metrics adapter answers the HPA's query at
            // reconcile time with the freshest sample it has (the paper's
            // PM-HPA "responds in milliseconds"); scraped history is the
            // fallback only.
            let target = metrics
                .latest(&name)
                .or_else(|| metrics.scraped(&name, now).map(|(v, _)| v))
                // No autoscaler metric → the ReplicaSet still restores the
                // deployment's own `replicas` field (crashed pods are
                // replaced even for unmanaged pools).
                .unwrap_or(d.desired as f64);
            let t = target.round().max(1.0) as u32;
            let delta = d.scale_to(t, now);
            if delta != 0 {
                changes.push((name, delta));
            }
        }
        changes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::deployment::DeploymentKey;

    fn dep(initial: u32) -> Deployment {
        Deployment::new(
            DeploymentKey {
                model: 0,
                instance: 0,
            },
            initial,
            8,
            1.8,
            30.0,
            0.0,
        )
    }

    #[test]
    fn reconcile_cadence() {
        let mut h = HpaController::new(5.0);
        assert!(h.due(0.0));
        let mut deps = vec![dep(1)];
        let m = MetricRegistry::new();
        h.reconcile(&mut deps, &m, 0.0);
        assert!(!h.due(4.9));
        assert!(h.due(5.0));
    }

    #[test]
    fn scales_to_custom_metric() {
        let mut h = HpaController::new(5.0);
        let mut deps = vec![dep(1)];
        let mut m = MetricRegistry::new();
        let name = MetricRegistry::scoped(DESIRED_REPLICAS, 0, 0);
        m.set(&name, 4.0, 0.0);
        m.scrape(0.0);
        let changes = h.reconcile(&mut deps, &m, 0.0);
        assert_eq!(changes, vec![(name, 3)]);
        assert_eq!(deps[0].active_count(), 4);
    }

    #[test]
    fn no_metric_no_change() {
        let mut h = HpaController::new(5.0);
        let mut deps = vec![dep(2)];
        let m = MetricRegistry::new();
        assert!(h.reconcile(&mut deps, &m, 0.0).is_empty());
        assert_eq!(deps[0].active_count(), 2);
    }

    #[test]
    fn respects_caps_and_floor() {
        let mut h = HpaController::new(5.0);
        let mut deps = vec![dep(2)];
        let mut m = MetricRegistry::new();
        let name = MetricRegistry::scoped(DESIRED_REPLICAS, 0, 0);
        m.set(&name, 100.0, 0.0);
        h.reconcile(&mut deps, &m, 0.0);
        assert_eq!(deps[0].active_count(), 8); // n_max
        m.set(&name, 0.0, 5.0);
        h.reconcile(&mut deps, &m, 5.0);
        assert_eq!(deps[0].desired, 1); // floor
    }

    #[test]
    fn idempotent_when_converged() {
        let mut h = HpaController::new(5.0);
        let mut deps = vec![dep(3)];
        let mut m = MetricRegistry::new();
        let name = MetricRegistry::scoped(DESIRED_REPLICAS, 0, 0);
        m.set(&name, 3.0, 0.0);
        assert!(h.reconcile(&mut deps, &m, 0.0).is_empty());
    }
}
