//! Pod lifecycle: Starting → Ready → Draining → (gone).
//!
//! A Starting pod consumes quota but serves nothing until `ready_at` —
//! this is the actuation lag that makes *reactive* autoscaling late and
//! *proactive* (PM-HPA) scaling valuable.

use crate::SimTime;

/// Lifecycle phase of one replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PodPhase {
    /// Container pulled/starting; serves no traffic until `ready_at`.
    Starting { ready_at: SimTime },
    /// Serving.
    Ready,
    /// Scale-in requested: finishes in-flight requests, accepts no new
    /// ones, force-killed at `deadline` (grace period).
    Draining { deadline: SimTime },
}

/// One replica of a model Deployment.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: u64,
    pub phase: PodPhase,
    /// Requests currently executing on this pod.
    pub in_flight: u32,
    /// When the pod was created (for debugging / mean-start metrics).
    pub created_at: SimTime,
}

impl Pod {
    pub fn new(id: u64, now: SimTime, startup: f64) -> Self {
        Pod {
            id,
            phase: PodPhase::Starting {
                ready_at: now + startup,
            },
            in_flight: 0,
            created_at: now,
        }
    }

    /// Progress lifecycle to `now`. Returns true if the pod should be
    /// removed (drain complete or grace deadline passed).
    pub fn tick(&mut self, now: SimTime) -> bool {
        match self.phase {
            PodPhase::Starting { ready_at } if now >= ready_at => {
                self.phase = PodPhase::Ready;
                false
            }
            PodPhase::Draining { deadline } => self.in_flight == 0 || now >= deadline,
            _ => false,
        }
    }

    /// Can this pod accept a new request at `now`?
    pub fn can_serve(&self, now: SimTime) -> bool {
        match self.phase {
            PodPhase::Ready => true,
            PodPhase::Starting { ready_at } => now >= ready_at,
            PodPhase::Draining { .. } => false,
        }
    }

    /// Begin draining (graceful termination, §IV-D step iii).
    pub fn drain(&mut self, now: SimTime, grace: f64) {
        if !matches!(self.phase, PodPhase::Draining { .. }) {
            self.phase = PodPhase::Draining {
                deadline: now + grace,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_delay_blocks_serving() {
        let p = Pod::new(1, 0.0, 1.8);
        assert!(!p.can_serve(0.0));
        assert!(!p.can_serve(1.7));
        assert!(p.can_serve(1.8));
    }

    #[test]
    fn tick_promotes_to_ready() {
        let mut p = Pod::new(1, 0.0, 1.8);
        assert!(!p.tick(1.0));
        assert!(matches!(p.phase, PodPhase::Starting { .. }));
        assert!(!p.tick(2.0));
        assert_eq!(p.phase, PodPhase::Ready);
    }

    #[test]
    fn draining_rejects_new_work() {
        let mut p = Pod::new(1, 0.0, 0.0);
        p.tick(0.0);
        p.in_flight = 1;
        p.drain(5.0, 30.0);
        assert!(!p.can_serve(5.0));
    }

    #[test]
    fn drain_completes_when_empty() {
        let mut p = Pod::new(1, 0.0, 0.0);
        p.tick(0.0);
        p.in_flight = 2;
        p.drain(5.0, 30.0);
        assert!(!p.tick(6.0)); // still has in-flight work
        p.in_flight = 0;
        assert!(p.tick(7.0)); // done gracefully
    }

    #[test]
    fn drain_force_kills_at_deadline() {
        let mut p = Pod::new(1, 0.0, 0.0);
        p.tick(0.0);
        p.in_flight = 1;
        p.drain(5.0, 30.0);
        assert!(!p.tick(34.9));
        assert!(p.tick(35.0)); // grace expired
    }

    #[test]
    fn drain_idempotent() {
        let mut p = Pod::new(1, 0.0, 0.0);
        p.tick(0.0);
        p.drain(5.0, 30.0);
        let d1 = p.phase;
        p.drain(10.0, 30.0); // must not extend the deadline
        assert_eq!(p.phase, d1);
    }
}
