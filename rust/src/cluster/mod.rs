//! Simulated Kubernetes substrate (§IV-D, §V-A.2).
//!
//! What the paper ran on a real cluster we model as explicit actuation
//! mechanics — because LA-IMR's benefit is precisely about *beating the
//! lags* of this machinery:
//! * pod startup ≈ 1.8 s (paper's measured ARM64 container start),
//! * HPA reconciliation every 5 s,
//! * Prometheus scrape staleness (reactive baselines see old metrics),
//! * graceful termination: draining pods finish in-flight work first.

mod deployment;
mod hpa;
mod metrics;
mod pod;

pub use deployment::{Deployment, DeploymentKey};
pub use hpa::HpaController;
pub use metrics::{MetricRegistry, DESIRED_REPLICAS};
pub use pod::{Pod, PodPhase};
