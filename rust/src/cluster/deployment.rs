//! Deployment: the replica pool for one (model, instance-class) pair.
//!
//! Owns pod lifecycle, exposes ready/desired counts, and implements
//! scale-out (new Starting pods) and graceful scale-in (drain the
//! youngest idle pods first — mirroring the ReplicaSet downscale
//! heuristic).

use super::pod::{Pod, PodPhase};
use crate::{InstanceId, ModelId, SimTime};

/// Identity of a deployment: ⟨model m, instance class i⟩ (§IV-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeploymentKey {
    pub model: ModelId,
    pub instance: InstanceId,
}

/// Replica pool with Kubernetes-like actuation mechanics.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub key: DeploymentKey,
    pub pods: Vec<Pod>,
    pub n_max: u32,
    startup: f64,
    drain_grace: f64,
    next_pod_id: u64,
    /// Desired count last requested (actuation may lag).
    pub desired: u32,
}

impl Deployment {
    pub fn new(
        key: DeploymentKey,
        initial: u32,
        n_max: u32,
        startup: f64,
        drain_grace: f64,
        now: SimTime,
    ) -> Self {
        let mut d = Deployment {
            key,
            pods: Vec::new(),
            n_max,
            startup,
            drain_grace,
            next_pod_id: 0,
            desired: 0,
        };
        // Initial replicas come up ready (the experiment starts warm, as
        // the paper's runs do).
        d.desired = initial.min(n_max);
        for _ in 0..d.desired {
            let id = d.next_pod_id;
            d.next_pod_id += 1;
            let mut p = Pod::new(id, now, 0.0);
            p.tick(now);
            d.pods.push(p);
        }
        d
    }

    /// Pods that can serve new requests now.
    pub fn ready_count(&self, now: SimTime) -> u32 {
        self.pods.iter().filter(|p| p.can_serve(now)).count() as u32
    }

    /// Pods that exist and are not draining (Starting + Ready): the replica
    /// count N the autoscaler reasons about.
    pub fn active_count(&self) -> u32 {
        self.pods
            .iter()
            .filter(|p| !matches!(p.phase, PodPhase::Draining { .. }))
            .count() as u32
    }

    /// Total in-flight requests across ready+draining pods.
    pub fn in_flight(&self) -> u32 {
        self.pods.iter().map(|p| p.in_flight).sum()
    }

    /// Scale to `target` replicas (bounded by n_max / ≥1), §IV-D step (ii):
    /// "scale out (or in) by the exact difference".
    /// Returns the signed delta actually actuated.
    pub fn scale_to(&mut self, target: u32, now: SimTime) -> i64 {
        let target = target.clamp(1, self.n_max);
        self.desired = target;
        let active = self.active_count();
        let mut delta: i64 = 0;
        if target > active {
            for _ in 0..(target - active) {
                let id = self.next_pod_id;
                self.next_pod_id += 1;
                self.pods.push(Pod::new(id, now, self.startup));
                delta += 1;
            }
        } else if target < active {
            // Drain youngest-first among non-draining pods, idle preferred.
            let mut to_drain = (active - target) as usize;
            let mut idx: Vec<usize> = (0..self.pods.len())
                .filter(|&k| !matches!(self.pods[k].phase, PodPhase::Draining { .. }))
                .collect();
            // Idle pods first, then youngest (highest id).
            idx.sort_by_key(|&k| (self.pods[k].in_flight, std::cmp::Reverse(self.pods[k].id)));
            for k in idx {
                if to_drain == 0 {
                    break;
                }
                self.pods[k].drain(now, self.drain_grace);
                to_drain -= 1;
                delta -= 1;
            }
        }
        delta
    }

    /// Progress pod lifecycles; removes completed pods. Returns how many
    /// pods became Ready during this tick (for pod-start telemetry).
    pub fn tick(&mut self, now: SimTime) -> u32 {
        let mut became_ready = 0;
        for p in &mut self.pods {
            let was_starting = matches!(p.phase, PodPhase::Starting { .. });
            let _ = p.tick(now);
            if was_starting && p.phase == PodPhase::Ready {
                became_ready += 1;
            }
        }
        self.pods.retain_mut(|p| !p.tick(now));
        became_ready
    }

    /// Pick the serving pod with the fewest in-flight requests
    /// (least-loaded within the pool ≈ the round-robin of Eq. 10 under
    /// symmetry, but strictly better under transients).
    pub fn pick_pod(&mut self, now: SimTime) -> Option<&mut Pod> {
        self.pods
            .iter_mut()
            .filter(|p| p.can_serve(now))
            .min_by_key(|p| p.in_flight)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dep(initial: u32) -> Deployment {
        Deployment::new(
            DeploymentKey {
                model: 0,
                instance: 0,
            },
            initial,
            8,
            1.8,
            30.0,
            0.0,
        )
    }

    #[test]
    fn initial_pods_ready_immediately() {
        let d = dep(2);
        assert_eq!(d.ready_count(0.0), 2);
        assert_eq!(d.active_count(), 2);
    }

    #[test]
    fn scale_out_has_startup_lag() {
        let mut d = dep(1);
        assert_eq!(d.scale_to(3, 10.0), 2);
        assert_eq!(d.active_count(), 3);
        assert_eq!(d.ready_count(10.0), 1); // 2 still Starting
        d.tick(11.8);
        assert_eq!(d.ready_count(11.8), 3); // 1.8 s later
    }

    #[test]
    fn scale_in_drains_gracefully() {
        let mut d = dep(3);
        d.pods[0].in_flight = 1;
        assert_eq!(d.scale_to(1, 5.0), -2);
        // Drained the two idle pods (youngest first); busy pod 0 kept.
        assert_eq!(d.active_count(), 1);
        d.tick(5.1);
        assert_eq!(d.pods.len(), 1);
        assert_eq!(d.pods[0].id, 0);
    }

    #[test]
    fn scale_bounded_by_n_max() {
        let mut d = dep(1);
        d.scale_to(100, 0.0);
        assert_eq!(d.active_count(), 8);
        assert_eq!(d.desired, 8);
    }

    #[test]
    fn never_scales_below_one() {
        let mut d = dep(2);
        d.scale_to(0, 0.0);
        assert_eq!(d.desired, 1);
        assert_eq!(d.active_count(), 1);
    }

    #[test]
    fn pick_pod_least_loaded() {
        let mut d = dep(3);
        d.pods[0].in_flight = 5;
        d.pods[1].in_flight = 1;
        d.pods[2].in_flight = 3;
        assert_eq!(d.pick_pod(0.0).unwrap().id, 1);
    }

    #[test]
    fn pick_pod_skips_draining_and_starting() {
        let mut d = dep(2);
        d.scale_to(3, 0.0); // pod 2 Starting
        d.pods[0].drain(0.0, 30.0);
        let picked = d.pick_pod(0.0).unwrap().id;
        assert_eq!(picked, 1);
    }

    #[test]
    fn busy_drained_pod_survives_until_done() {
        let mut d = dep(2);
        d.pods[0].in_flight = 1;
        d.pods[1].in_flight = 1;
        d.scale_to(1, 0.0);
        d.tick(1.0);
        assert_eq!(d.pods.len(), 2); // both busy, drain pending
        // Find the draining pod and finish its work.
        for p in &mut d.pods {
            if matches!(p.phase, PodPhase::Draining { .. }) {
                p.in_flight = 0;
            }
        }
        d.tick(2.0);
        assert_eq!(d.pods.len(), 1);
    }

    #[test]
    fn scale_delta_is_exact_difference() {
        let mut d = dep(2);
        assert_eq!(d.scale_to(5, 0.0), 3);
        assert_eq!(d.scale_to(5, 0.0), 0);
        assert_eq!(d.scale_to(4, 0.0), -1);
    }
}
