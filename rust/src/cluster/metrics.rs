//! Prometheus-like metric registry with scrape semantics.
//!
//! Two read paths with *different freshness*, because that asymmetry is
//! the paper's core argument:
//! * `set`/`latest` — instant, in-process (what LA-IMR itself uses);
//! * `scrape`/`scraped` — values sampled only every scrape interval (what
//!   a reactive CPU/latency autoscaler sees: stale by up to one period).

use crate::SimTime;
use std::collections::HashMap;

/// The custom metric name PM-HPA exports (§IV-D).
pub const DESIRED_REPLICAS: &str = "desired_replicas";

#[derive(Debug, Clone, Copy, Default)]
struct Sample {
    value: f64,
    at: SimTime,
}

/// Named gauge registry with scrape-lagged reads.
#[derive(Debug, Default)]
pub struct MetricRegistry {
    live: HashMap<String, Sample>,
    scraped: HashMap<String, Sample>,
    last_scrape: SimTime,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set a gauge (in-process write — microseconds in the real system).
    pub fn set(&mut self, name: &str, value: f64, now: SimTime) {
        self.live
            .insert(name.to_string(), Sample { value, at: now });
    }

    /// Increment a counter-style gauge.
    pub fn add(&mut self, name: &str, delta: f64, now: SimTime) {
        let e = self.live.entry(name.to_string()).or_default();
        e.value += delta;
        e.at = now;
    }

    /// Instant read (LA-IMR's in-memory path).
    pub fn latest(&self, name: &str) -> Option<f64> {
        self.live.get(name).map(|s| s.value)
    }

    /// Run a scrape: copy live values into the scraped snapshot.
    pub fn scrape(&mut self, now: SimTime) {
        self.scraped = self.live.clone();
        self.last_scrape = now;
    }

    /// Read through the scrape path — stale by up to one scrape period.
    /// Returns (value, age_at(now)).
    pub fn scraped(&self, name: &str, now: SimTime) -> Option<(f64, f64)> {
        self.scraped
            .get(name)
            .map(|s| (s.value, (now - s.at).max(0.0)))
    }

    pub fn last_scrape(&self) -> SimTime {
        self.last_scrape
    }

    /// Conventional metric name for a deployment-scoped gauge.
    pub fn scoped(name: &str, model: usize, instance: usize) -> String {
        format!("{name}{{m={model},i={instance}}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_reads_are_instant() {
        let mut r = MetricRegistry::new();
        r.set("x", 1.0, 0.0);
        assert_eq!(r.latest("x"), Some(1.0));
        r.set("x", 2.0, 0.1);
        assert_eq!(r.latest("x"), Some(2.0));
    }

    #[test]
    fn scraped_reads_are_stale() {
        let mut r = MetricRegistry::new();
        r.set("p95", 1.0, 0.0);
        r.scrape(0.0);
        r.set("p95", 9.0, 5.0); // spike after the scrape
        // Reactive controller still sees the old value.
        let (v, age) = r.scraped("p95", 10.0).unwrap();
        assert_eq!(v, 1.0);
        assert!((age - 10.0).abs() < 1e-12);
        r.scrape(15.0);
        assert_eq!(r.scraped("p95", 15.0).unwrap().0, 9.0);
    }

    #[test]
    fn missing_metric_none() {
        let r = MetricRegistry::new();
        assert_eq!(r.latest("nope"), None);
        assert_eq!(r.scraped("nope", 1.0), None);
    }

    #[test]
    fn add_accumulates() {
        let mut r = MetricRegistry::new();
        r.add("count", 1.0, 0.0);
        r.add("count", 2.0, 1.0);
        assert_eq!(r.latest("count"), Some(3.0));
    }

    #[test]
    fn scoped_name_format() {
        assert_eq!(
            MetricRegistry::scoped(DESIRED_REPLICAS, 1, 0),
            "desired_replicas{m=1,i=0}"
        );
    }
}
