//! Fig 2 bench: calibration speed + reproduction of the paper's fit
//! (α=0.73, β=1.29, γ=1.49 on its own Table IV data).

use la_imr::config::Config;
use la_imr::latency_model::{fit_anchored, paper_table4_samples};
use la_imr::report;
use la_imr::sim::Runner;
use la_imr::util::bench::{bench, bench_once, black_box};

fn main() {
    let samples = paper_table4_samples();
    bench("fit_anchored (golden-section, 12 samples)", 30, || {
        black_box(fit_anchored(&samples, 0.73, 0.3, 3.0));
    });
    let fit = fit_anchored(&samples, 0.73, 0.3, 3.0).unwrap();
    println!(
        "  paper-data fit: α={:.2} β={:.3} γ={:.3} R²={:.4} (paper: 0.73/1.29/1.49)",
        fit.alpha, fit.beta, fit.gamma, fit.r_squared
    );
    let cfg = Config::default();
    let runner = Runner::new();
    let (txt, _) = bench_once("fig2: full calibration report", || {
        report::fig2(&cfg, &runner)
    });
    println!("{txt}");
}
