//! L3 hot-path microbenchmarks — the paper's "microseconds of access
//! time, millisecond-level responses" claim (§I). Targets (DESIGN.md
//! §Perf): < 5 µs per routing decision; each telemetry primitive O(1).

use la_imr::config::Config;
use la_imr::coordinator::state::ReplicaView;
use la_imr::coordinator::{ControlState, Router};
use la_imr::latency_model::LatencyModel;
use la_imr::queueing;
use la_imr::telemetry::{Ewma, LatencyHistogram, SlidingRate};
use la_imr::util::bench::{bench, black_box};

fn main() {
    let cfg = Config::default();
    let (yolo, _) = cfg.model_by_name("yolov5m").unwrap();

    // Full Algorithm-1 routing decision (table path — production config).
    let mut router = Router::new(&cfg);
    let mut state = ControlState::new();
    for m in 0..cfg.models.len() {
        for i in 0..cfg.instances.len() {
            state.update(
                la_imr::cluster::DeploymentKey { model: m, instance: i },
                ReplicaView { active: 4, ready: 4, desired: 4, rho: 0.5, queue_depth: 2 },
            );
        }
    }
    let mut now = 0.0;
    bench("router::route (Algorithm 1, table lookup)", 50, || {
        now += 0.01;
        black_box(router.route(yolo, now, &state));
    });

    // Ablation: direct closed-form evaluation instead of the table.
    let mut router2 = Router::new(&cfg);
    router2.use_table = false;
    let mut now2 = 0.0;
    bench("router::route (direct powf evaluation)", 50, || {
        now2 += 0.01;
        black_box(router2.route(yolo, now2, &state));
    });

    // Telemetry primitives.
    let mut rate = SlidingRate::new(1.0);
    let mut t = 0.0;
    bench("telemetry::SlidingRate::on_arrival", 50, || {
        t += 0.001;
        black_box(rate.on_arrival(t));
    });
    let mut ewma = Ewma::new(0.8);
    bench("telemetry::Ewma::update", 50, || {
        black_box(ewma.update(4.2));
    });
    let mut hist = LatencyHistogram::for_latency();
    bench("telemetry::LatencyHistogram::record", 50, || {
        hist.record(0.73);
    });
    bench("telemetry::LatencyHistogram::p99", 50, || {
        black_box(hist.p99());
    });

    // Model evaluation primitives.
    let lm = LatencyModel::from_config(&cfg, yolo, 0);
    bench("latency_model::g_lambda (Eq. 15)", 50, || {
        black_box(lm.g_lambda(black_box(3.3), 4));
    });
    bench("queueing::erlang_c (c=8)", 50, || {
        black_box(queueing::erlang_c(black_box(5.5), 8));
    });
    bench("latency_model::required_replicas", 50, || {
        black_box(lm.required_replicas(black_box(4.0), 1.64, 16));
    });
}
