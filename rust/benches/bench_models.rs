//! Table II bench: real PJRT inference wall-clock per model + the L1
//! kernel-dominated cost gap between the quality tiers.

use la_imr::config::QualityClass;
use la_imr::runtime::{postprocess, Runtime};
use la_imr::util::bench::{bench, black_box};
use la_imr::workload::RobotFleet;

fn main() {
    let rt = match Runtime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping bench_models: {e}");
            return;
        }
    };
    let fleet = RobotFleet::uniform(1, 1.0, QualityClass::Balanced);
    println!("Table II — PJRT-CPU inference cost per model");
    for name in rt.model_names() {
        let model = rt.model(name).unwrap();
        let img = fleet.frame(0, 0, model.entry.input_shape[1]);
        let _ = model.infer(&img).unwrap(); // warm
        bench(&format!("infer::{name}"), 20, || {
            black_box(model.infer(&img).unwrap());
        });
    }
    // Post-processing is not the bottleneck.
    let model = rt.model("yolov5m").unwrap();
    let img = fleet.frame(0, 0, model.entry.input_shape[1]);
    let out = model.infer(&img).unwrap();
    bench("postprocess::yolov5m", 30, || {
        black_box(postprocess(&out, rt.manifest.num_classes, 0.52));
    });
    // Frame synthesis (workload generator cost).
    bench("workload::frame 96x96", 30, || {
        black_box(fleet.frame(0, 1, 96));
    });
}
