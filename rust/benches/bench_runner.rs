//! Sharded-runner bench: the same experiment grid, serial vs parallel.
//!
//! Two claims under test (ISSUE 1 acceptance):
//! * wall-clock: the parallel sweep must be measurably faster than the
//!   serial one on multi-core hosts;
//! * determinism: both schedules must produce bit-identical statistics
//!   (per-cell seeding, no shared RNG).

use la_imr::config::{Config, ScenarioConfig};
use la_imr::sim::{Cell, Policy, Runner};
use la_imr::util::bench::bench_once;

fn grid() -> Vec<Cell> {
    let mut cells = Vec::new();
    for lam in 1..=6 {
        for seed in [101u64, 102, 103] {
            for policy in [Policy::LaImr, Policy::Baseline, Policy::Hedged] {
                cells.push(Cell::new(
                    ScenarioConfig::bursty(lam as f64, seed)
                        .with_duration(120.0, 10.0)
                        .with_replicas(2),
                    policy,
                ));
            }
        }
    }
    cells
}

fn main() {
    let cfg = Config::default();
    let cells = grid();
    println!(
        "runner grid: {} cells (λ=1..6 × 3 seeds × 3 policies, 120 s each)",
        cells.len()
    );

    let (serial, t_serial) = bench_once("runner: serial (1 worker)", || {
        Runner::serial().run(&cfg, &cells)
    });
    let parallel_runner = Runner::new();
    let (parallel, t_parallel) = bench_once("runner: parallel (auto workers)", || {
        parallel_runner.run(&cfg, &cells)
    });

    // Determinism: identical latency series cell by cell.
    for (k, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(
            a.latencies(),
            b.latencies(),
            "cell {k} diverged between serial and parallel runs"
        );
        assert_eq!(a.scale_outs, b.scale_outs, "cell {k} scaling diverged");
    }
    println!("  determinism: serial == parallel across all {} cells ✓", cells.len());

    let speedup = t_serial / t_parallel.max(1e-9);
    println!(
        "  wall-clock: serial {t_serial:.2}s vs parallel {t_parallel:.2}s on {} workers → {speedup:.2}x",
        parallel_runner.threads()
    );
    if parallel_runner.threads() > 1 {
        assert!(
            speedup > 1.2,
            "parallel sweep not measurably faster ({speedup:.2}x on {} workers)",
            parallel_runner.threads()
        );
    }
}
