//! Fig 4 bench: microservice vs monolithic architecture sweep over N at
//! λ=4 with mixed-quality traffic.

use la_imr::config::Config;
use la_imr::report;
use la_imr::sim::Runner;
use la_imr::util::bench::bench_once;

fn main() {
    let cfg = Config::default();
    let runner = Runner::new();
    let (data, dt) = bench_once("fig4: micro vs mono, N ∈ {1,2,4,6}", || {
        report::fig4_data(&cfg, 150.0, &runner)
    });
    println!("  regenerated in {dt:.2}s");
    println!("  N   micro P99   mono P99   mono/micro");
    for (n, micro, mono) in &data {
        println!(
            "  {n}   {:>8.2}   {:>8.2}   {:>9.2}x",
            micro.p99,
            mono.p99,
            mono.p99 / micro.p99.max(1e-9)
        );
    }
    // The paper's claim: microservice wins, especially at larger N.
    let last = data.last().unwrap();
    assert!(last.2.p99 >= last.1.p99, "monolithic unexpectedly won");
}
