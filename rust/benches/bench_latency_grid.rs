//! Table IV bench: regenerate the λ×N latency grid and check its shape
//! against the paper (idle ≈ L_m, growth with λ, relief with N).

use la_imr::config::Config;
use la_imr::report;
use la_imr::sim::Runner;
use la_imr::util::bench::bench_once;

fn main() {
    let cfg = Config::default();
    let runner = Runner::new();
    let (cells, dt) = bench_once("table4: 12-cell grid × 3 seeds", || {
        report::table4_data(&cfg, report::TABLE4_WINDOW, &runner)
    });
    println!("  grid regenerated in {dt:.2}s (paper's testbed: ~12 cluster-runs)");
    let get = |n: u32, lam: f64| cells.iter().find(|c| c.0 == n && c.1 == lam).unwrap().2;
    println!("  shape checks:");
    println!("    idle cell (N=4, λ=1) = {:.2}s  (paper 0.73)", get(4, 1.0));
    println!(
        "    overload growth N=1: {:.1} → {:.1} → {:.1} → {:.1}",
        get(1, 1.0), get(1, 2.0), get(1, 3.0), get(1, 4.0)
    );
    println!(
        "    relief at λ=4: N=1 {:.1} → N=2 {:.1} → N=4 {:.1}",
        get(1, 4.0), get(2, 4.0), get(4, 4.0)
    );
    assert!(get(1, 4.0) > get(1, 1.0) && get(1, 4.0) > get(4, 4.0));
    println!("{}", report::table4(&cfg, &runner));
}
