//! Fig 7/8 bench: the end-to-end head-to-head (LA-IMR vs reactive
//! baseline vs hedged) across λ = 1..6 under bounded-Pareto bursts, plus
//! DES throughput (simulated events per wall-second — the harness must
//! stay fast enough to sweep the full grid in seconds).

use la_imr::config::{Config, ScenarioConfig};
use la_imr::report;
use la_imr::sim::{Architecture, Policy, Runner, Simulation};
use la_imr::telemetry::{box_stats, Summary};
use la_imr::util::bench::bench_once;

fn main() {
    let cfg = Config::default();

    // DES throughput: one 300 s λ=6 LA-IMR run.
    let scenario = ScenarioConfig::bursty(6.0, 42)
        .with_duration(300.0, 30.0)
        .with_replicas(2);
    let (r, dt) = bench_once("end2end: 300s λ=6 LA-IMR scenario", || {
        Simulation::new(&cfg, &scenario, Policy::LaImr, Architecture::Microservice).run()
    });
    println!(
        "  {} events / {} completions in {dt:.3}s wall → {:.0} events/s, {:.0} requests/s; sim/real ratio {:.0}x",
        r.events,
        r.completed.len(),
        r.events as f64 / dt,
        r.completed.len() as f64 / dt,
        300.0 / dt
    );

    let runner = Runner::new();
    let (data, dt) = bench_once("fig7/8: λ=1..6 × 4 policies × 3 seeds", || {
        report::head_to_head(&cfg, 300.0, &[101, 102, 103], &runner)
    });
    println!("  full sweep in {dt:.2}s on {} workers\n", runner.threads());
    println!("  λ   LA-IMR P50/P95/P99      baseline P50/P95/P99    hedged P50/P95/P99     IQR(LA)  IQR(BL)");
    for h in &data {
        // Pooled series index like report::SWEEP_POLICIES.
        let la = Summary::from(&h.all[0]);
        let bl = Summary::from(&h.all[1]);
        let hd = Summary::from(&h.all[2]);
        let (bla, blb) = (box_stats(&h.all[0]), box_stats(&h.all[1]));
        println!(
            "  {}   {:5.2}/{:5.2}/{:5.2}      {:5.2}/{:5.2}/{:5.2}      {:5.2}/{:5.2}/{:5.2}     {:6.2}  {:6.2}",
            h.lambda,
            la.p50,
            la.p95,
            la.p99,
            bl.p50,
            bl.p95,
            bl.p99,
            hd.p50,
            hd.p95,
            hd.p99,
            bla.iqr,
            blb.iqr
        );
    }
}
