//! Table VI bench: the headline P95/P99 mean±SD comparison across
//! λ = 1..6 (5 seeds per cell, LA-IMR vs baseline vs hedged), printing
//! paper-format rows and the P99-gain trend that must grow with load.

use la_imr::config::Config;
use la_imr::report;
use la_imr::sim::Runner;
use la_imr::util::bench::bench_once;

fn main() {
    let cfg = Config::default();
    let runner = Runner::new();
    let (txt, dt) = bench_once("table6: λ=1..6 × 3 policies × 5 seeds", || {
        report::table6(&cfg, &runner)
    });
    println!(
        "  regenerated in {dt:.2}s on {} workers  (paper's testbed: ~60 cluster-runs)\n",
        runner.threads()
    );
    println!("{txt}");
    // Shape assertions: LA-IMR never loses on P99; σ shrinks at λ=6.
    let data = report::head_to_head(&cfg, 300.0, &[101, 102, 103, 104, 105], &runner);
    for h in &data {
        assert!(
            h.la_p99.mean <= h.bl_p99.mean * 1.05,
            "LA-IMR lost at λ={}",
            h.lambda
        );
    }
    let last = data.last().unwrap();
    assert!(
        last.la_p99.std < last.bl_p99.std,
        "P99 σ reduction missing at λ=6"
    );
    println!(
        "  λ=6 P99 σ: LA-IMR {:.2}s vs baseline {:.2}s ({:.0}% reduction; paper >60%)",
        last.la_p99.std,
        last.bl_p99.std,
        100.0 * (1.0 - last.la_p99.std / last.bl_p99.std)
    );
}
