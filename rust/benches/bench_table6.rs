//! Table VI bench: the headline P95/P99 mean±SD comparison across
//! λ = 1..6 (5 seeds per cell, LA-IMR vs baseline vs hedged), printing
//! paper-format rows and the P99-gain trend that must grow with load.

use la_imr::config::Config;
use la_imr::report;
use la_imr::sim::Runner;
use la_imr::util::bench::bench_once;

fn main() {
    let cfg = Config::default();
    let runner = Runner::new();
    let (txt, dt) = bench_once("table6: λ=1..6 × 4 policies × 5 seeds", || {
        report::table6(&cfg, &runner)
    });
    println!(
        "  regenerated in {dt:.2}s on {} workers  (paper's testbed: ~60 cluster-runs)\n",
        runner.threads()
    );
    println!("{txt}");
    // Shape assertions: LA-IMR never loses on P99; σ shrinks at λ=6.
    // (Per-policy vectors index like report::SWEEP_POLICIES — LA-IMR is
    // 0, the reactive baseline 1.)
    let data = report::head_to_head(&cfg, 300.0, &[101, 102, 103, 104, 105], &runner);
    for h in &data {
        assert!(
            h.p99[0].mean <= h.p99[1].mean * 1.05,
            "LA-IMR lost at λ={}",
            h.lambda
        );
    }
    let last = data.last().unwrap();
    assert!(
        last.p99[0].std < last.p99[1].std,
        "P99 σ reduction missing at λ=6"
    );
    println!(
        "  λ=6 P99 σ: LA-IMR {:.2}s vs baseline {:.2}s ({:.0}% reduction; paper >60%)",
        last.p99[0].std,
        last.p99[1].std,
        100.0 * (1.0 - last.p99[0].std / last.p99[1].std)
    );
}
