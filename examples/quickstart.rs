//! Quickstart: the 60-second tour of LA-IMR.
//!
//! 1. Load the AOT artifacts and run one real PJRT inference.
//! 2. Evaluate the closed-form latency model (Eq. 15/17).
//! 3. Route a handful of requests through Algorithm 1, showing the
//!    instant-offload and scale-out decisions fire (Fig 5's control flow).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use la_imr::config::{Config, QualityClass};
use la_imr::coordinator::state::ReplicaView;
use la_imr::coordinator::{ControlState, Router};
use la_imr::latency_model::LatencyModel;
use la_imr::runtime::{postprocess, Runtime};
use la_imr::workload::RobotFleet;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();

    // ---- 1. Real inference through the PJRT runtime -------------------
    println!("== 1. PJRT inference (python is not involved) ==");
    match Runtime::load(std::path::Path::new("artifacts")) {
        Err(e) => println!("   (skipped: {e}; run `make artifacts`)"),
        Ok(rt) => {
            let fleet = RobotFleet::uniform(1, 1.0, QualityClass::Balanced);
            for name in rt.model_names() {
                let model = rt.model(name).unwrap();
                let golden_err = model.golden_check()?;
                let img = fleet.frame(0, 0, model.entry.input_shape[1]);
                let t = model.time_one(&img)?;
                let out = model.infer(&img)?;
                let dets = postprocess(&out, rt.manifest.num_classes, 0.52);
                println!(
                    "   {name:<12} {:>6.2} ms/frame  {} detections  (golden err {golden_err:.1e})",
                    t * 1e3,
                    dets.len()
                );
            }
        }
    }

    // ---- 2. The closed-form latency model ------------------------------
    println!("\n== 2. Closed-form latency model g(λ, N) for YOLOv5m on edge ==");
    let (yolo, _) = cfg.model_by_name("yolov5m").unwrap();
    let lm = LatencyModel::from_config(&cfg, yolo, 0);
    let tau = cfg.slo_budget(yolo);
    println!(
        "   SLO budget τ = x·L_m = {:.2}·{:.2} = {tau:.2} s",
        cfg.slo.x_multiplier, 0.73
    );
    for lam in [1.0, 2.0, 4.0, 6.0] {
        print!("   λ={lam}: ");
        for n in [1u32, 2, 4, 8] {
            let g = lm.g_lambda(lam, n);
            if g.is_finite() {
                print!("g(N={n})={g:.2}s{} ", if g <= tau { "✓" } else { "✗" });
            } else {
                print!("g(N={n})=∞ ");
            }
        }
        let need = lm.required_replicas(lam, tau, 16);
        println!("→ PM-HPA target N = {need:?}");
    }

    // ---- 3. Algorithm 1 in action --------------------------------------
    println!("\n== 3. Algorithm 1: route, offload, scale (Fig 5 flow) ==");
    let mut router = Router::new(&cfg);
    let mut state = ControlState::new();
    let home = router.home(yolo);
    state.update(
        home,
        ReplicaView {
            active: 1,
            ready: 1,
            desired: 1,
            rho: 0.6,
            queue_depth: 0,
        },
    );
    // A burst of 10 requests inside one second.
    for k in 0..10 {
        let now = 0.1 * k as f64;
        let d = router.route(yolo, now, &state);
        println!(
            "   t={now:.1}s → {:?} target=(m{},i{}) predicted={:.2}s{}",
            d.reason,
            d.target.model,
            d.target.instance,
            d.predicted,
            if d.desired_updates.is_empty() {
                String::new()
            } else {
                format!("  publish desired_replicas={}", d.desired_updates[0].1)
            }
        );
    }
    println!("\nNext: `laimr simulate --lambda 4 --policy la-imr` or `laimr repro all`.");
    Ok(())
}
