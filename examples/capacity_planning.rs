//! Capacity planning (Eq. 23): size replica pools for a forecast traffic
//! mix, sweeping the cost–latency trade-off β — the paper's "slower
//! capacity-planning optimisation" instantiation g(N).
//!
//! Run: `cargo run --release --example capacity_planning`

use la_imr::config::{Config, QualityClass};
use la_imr::planner::{plan_capacity, route_tasks, RoutingProblem, TaskClass};

fn main() {
    let cfg = Config::default();
    let (yolo, _) = cfg.model_by_name("yolov5m").unwrap();
    let tau = cfg.slo_budget(yolo);

    // Forecast: 4 req/s balanced robot traffic + 1 req/s precision
    // inspection + 3 req/s low-latency safety stops.
    let classes = vec![
        TaskClass {
            name: "safety-stop".into(),
            quality: QualityClass::LowLatency,
            lambda: 3.0,
            slo: Some(0.5),
            min_accuracy: 0.2,
        },
        TaskClass {
            name: "manipulation".into(),
            quality: QualityClass::Balanced,
            lambda: 4.0,
            slo: Some(tau),
            min_accuracy: 0.5,
        },
        TaskClass {
            name: "inspection".into(),
            quality: QualityClass::Precise,
            lambda: 1.0,
            slo: Some(8.0),
            min_accuracy: 0.7,
        },
    ];

    println!("capacity plans across the β sweep (Eq. 23 objective):");
    println!("{:>8} {:>14} {:>10} {:>12}  layout", "β", "worst-lat [s]", "cost", "objective");
    for beta in [0.1, 1.0, 2.5, 10.0, 40.0] {
        match plan_capacity(&cfg, &classes, beta) {
            None => println!("{beta:>8}  infeasible"),
            Some(plan) => {
                let mut layout = String::new();
                for (m, row) in plan.replicas.iter().enumerate() {
                    for (i, &n) in row.iter().enumerate() {
                        if n > 0 {
                            layout.push_str(&format!(
                                "{}@{}×{} ",
                                cfg.models[m].name, cfg.instances[i].name, n
                            ));
                        }
                    }
                }
                println!(
                    "{beta:>8} {:>14.3} {:>10.1} {:>12.2}  {layout}",
                    plan.worst_latency, plan.cost, plan.objective
                );
            }
        }
    }

    // Then: route the same classes over the β=2.5 layout (Eq. 18).
    let plan = plan_capacity(&cfg, &classes, cfg.slo.beta_cost).expect("feasible");
    let routing = route_tasks(
        &cfg,
        &RoutingProblem {
            classes: classes.clone(),
            replicas: plan.replicas.clone(),
        },
    )
    .expect("routable");
    println!("\nrouting over the β={} layout (Eq. 18 min-max):", cfg.slo.beta_cost);
    for p in routing {
        println!(
            "  {:<14} → {} on {} (predicted {:.3} s, SLO {:?})",
            classes[p.class].name,
            cfg.models[p.model].name,
            cfg.instances[p.instance].name,
            p.latency,
            classes[p.class].slo
        );
    }
}
