//! Perf-trajectory harness: measures the DES hot path and the memoized
//! runner, prints the numbers, and writes them to `BENCH_RESULTS.json`
//! so the repo carries a recorded performance baseline from PR 2 onward
//! (regenerate after perf-relevant changes and commit the diff — git
//! history *is* the trajectory).
//!
//!   cargo run --release --example bench_baseline                # full
//!   cargo run --release --example bench_baseline -- --smoke     # CI
//!   cargo run --release --example bench_baseline -- --out path.json
//!
//! Five measurements:
//!   * `cold_single_pass` — one λ=6 bursty LA-IMR simulation: simulated
//!     events drained per wall-second (the dense-index engine path);
//!   * `sweep_cold` — a λ×seed×policy grid with memoization disabled:
//!     cells per second (the sharded runner's raw throughput);
//!   * `sweep_repeated` — the same grid requested 3× (the shape of
//!     `repro all`, where Table VI and Figs 7/8 share cells), cold vs
//!     memoized: the memo speedup, with results verified bit-identical;
//!   * `million_robot` — the ISSUE 6 yardstick: the ~10⁶-request smooth
//!     scenario (smoke: ~60k) under `engine.mode = des` vs `hybrid`,
//!     reporting per-mode wall time, request throughput, how many
//!     completions the fluid fast path batched, and the process peak
//!     RSS (the chunk-streamed arrival front end bounds it);
//!   * `store_sweep` — the ISSUE 10 warm-start yardstick: the same grid
//!     against an empty persistent store (cold: computes + writes) then
//!     from a fresh runner and fresh store handle (warm: loads only),
//!     reporting the cold/warm wall times, the speedup, and the hit
//!     rate — with zero computes and bit-identity asserted.

use la_imr::config::{Config, EngineMode, ScenarioConfig};
use la_imr::report::{million_robot_config, million_robot_scenario};
use la_imr::sim::{Architecture, Cell, Policy, ResultStore, Runner, Simulation};
use la_imr::util::bench::{bench_once, peak_rss_bytes};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

fn arg_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn grid(duration: f64, trials: &[u64]) -> Vec<Cell> {
    let mut cells = Vec::new();
    for lam in 1..=6 {
        for &seed in trials {
            for policy in [Policy::LaImr, Policy::Baseline, Policy::Hedged] {
                cells.push(Cell::new(
                    ScenarioConfig::bursty(lam as f64, seed)
                        .with_duration(duration, duration / 10.0)
                        .with_replicas(2),
                    policy,
                ));
            }
        }
    }
    cells
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let out_path = arg_value("--out").unwrap_or_else(|| "BENCH_RESULTS.json".into());
    let (duration, trials): (f64, &[u64]) = if smoke {
        (60.0, &[101, 102])
    } else {
        (300.0, &[101, 102, 103])
    };
    let cfg = Config::default();
    let runner_threads = Runner::new().threads();
    println!(
        "bench_baseline ({} mode): {}s cells, {} seeds, {} workers\n",
        if smoke { "smoke" } else { "full" },
        duration,
        trials.len(),
        runner_threads
    );

    // 1) Cold single-pass DES throughput (engine hot path).
    let scenario = ScenarioConfig::bursty(6.0, 42)
        .with_duration(duration, duration / 10.0)
        .with_replicas(2);
    let (r, cold_dt) = bench_once("cold: single λ=6 LA-IMR pass", || {
        Simulation::new(&cfg, &scenario, Policy::LaImr, Architecture::Microservice).run()
    });
    let events_per_sec = r.events as f64 / cold_dt.max(1e-9);
    println!(
        "  {} events, {} completions → {:.0} events/s ({:.0}x real time)\n",
        r.events,
        r.completed.len(),
        events_per_sec,
        duration / cold_dt.max(1e-9)
    );

    // 2) Cold sweep (no memo): raw sharded-runner throughput.
    let cells = grid(duration, trials);
    let cold_runner = Runner::new().without_cache();
    let (cold_results, sweep_cold_dt) = bench_once(
        &format!("sweep cold: {} cells, no cache", cells.len()),
        || cold_runner.run(&cfg, &cells),
    );
    let cold_cells_per_sec = cells.len() as f64 / sweep_cold_dt.max(1e-9);
    println!("  {:.2} cells/s\n", cold_cells_per_sec);

    // 3) Repeated-cell workload (the `repro all` shape): same grid 3×.
    let repeated: Vec<Cell> = (0..3).flat_map(|_| cells.iter().cloned()).collect();
    let rep_runner_cold = Runner::new().without_cache();
    let (_, rep_cold_dt) = bench_once(
        &format!("sweep repeated×3: {} cells, no cache", repeated.len()),
        || rep_runner_cold.run(&cfg, &repeated),
    );
    let memo_runner = Runner::new();
    let (memo_results, rep_memo_dt) = bench_once(
        &format!("sweep repeated×3: {} cells, memoized", repeated.len()),
        || memo_runner.run(&cfg, &repeated),
    );
    let memo_speedup = rep_cold_dt / rep_memo_dt.max(1e-9);
    println!(
        "  memoization speedup on repeated cells: {:.2}x ({} distinct cells computed)\n",
        memo_speedup,
        memo_runner.cache_len().unwrap_or(0)
    );

    // Memo hits must be bit-identical to the cold sweep, cell for cell.
    for (k, (a, b)) in cold_results.iter().zip(&memo_results).enumerate() {
        assert_eq!(
            a.latencies(),
            b.latencies(),
            "memoized cell {k} diverged from cold run"
        );
    }
    println!("  bit-identity: memoized == cold across all cells ✓\n");

    // 4) Million-robot fast path (ISSUE 6): the big smooth scenario under
    //    both engine modes. Same arrivals by construction; hybrid must
    //    batch a large share of completions through the fluid path.
    let mr_cfg = million_robot_config();
    let mr_scenario = million_robot_scenario(7, smoke);
    let mut mr_hybrid_cfg = mr_cfg.clone();
    mr_hybrid_cfg.engine.mode = EngineMode::Hybrid;
    let arch = Architecture::Microservice;
    let (mr_des, mr_des_dt) = bench_once(
        &format!("million-robot ({}): engine.mode=des", mr_scenario.name),
        || Simulation::new(&mr_cfg, &mr_scenario, Policy::Static, arch).run(),
    );
    let (mr_hyb, mr_hyb_dt) = bench_once(
        &format!("million-robot ({}): engine.mode=hybrid", mr_scenario.name),
        || Simulation::new(&mr_hybrid_cfg, &mr_scenario, Policy::Static, arch).run(),
    );
    assert_eq!(
        mr_des.generated, mr_hyb.generated,
        "engine modes saw different million-robot arrival streams"
    );
    let mr_des_rps = mr_des.generated as f64 / mr_des_dt.max(1e-9);
    let mr_hyb_rps = mr_hyb.generated as f64 / mr_hyb_dt.max(1e-9);
    let mr_speedup = mr_des_dt / mr_hyb_dt.max(1e-9);
    let peak_rss_mb = peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0));
    println!(
        "  {} requests: des {:.0} req/s ({} events), hybrid {:.0} req/s \
         ({} events, {} fluid-batched) → {:.2}x; peak RSS {}\n",
        mr_des.generated,
        mr_des_rps,
        mr_des.events,
        mr_hyb_rps,
        mr_hyb.events,
        mr_hyb.fluid_batched,
        mr_speedup,
        peak_rss_mb.map_or_else(|| "n/a".into(), |mb| format!("{mb:.0} MiB")),
    );

    // 5) Persistent-store warm start (ISSUE 10): the same grid against an
    //    empty store (cold), then from a fresh runner *and* a fresh store
    //    handle — the shape of a new process warm-starting off disk. The
    //    fresh handle's tally proves the warm pass computed nothing.
    let store_dir = std::env::temp_dir().join(format!(
        "laimr-bench-store-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&store_dir);
    let cold_store = Arc::new(ResultStore::open(&store_dir).expect("open bench store"));
    let store_cold_runner = Runner::new().with_store(Arc::clone(&cold_store));
    let (store_cold_results, store_cold_dt) = bench_once(
        &format!("store sweep cold: {} cells, empty store", cells.len()),
        || store_cold_runner.run(&cfg, &cells),
    );
    let warm_store = Arc::new(ResultStore::open(&store_dir).expect("open bench store"));
    let store_warm_runner = Runner::new().with_store(Arc::clone(&warm_store));
    let (store_warm_results, store_warm_dt) = bench_once(
        &format!("store sweep warm: {} cells, fresh runner + handle", cells.len()),
        || store_warm_runner.run(&cfg, &cells),
    );
    let warm_tally = warm_store.tally();
    assert_eq!(warm_tally.writes, 0, "warm store sweep must compute nothing");
    let warm_hit_rate = warm_tally.hits as f64 / cells.len() as f64;
    for (k, (a, b)) in store_cold_results.iter().zip(&store_warm_results).enumerate() {
        assert_eq!(
            a.latencies(),
            b.latencies(),
            "store-warmed cell {k} diverged from cold run"
        );
    }
    let store_speedup = store_cold_dt / store_warm_dt.max(1e-9);
    println!(
        "  cold {:.3}s → warm {:.3}s ({:.2}x; {} hits, 0 computes, bit-identical ✓)\n",
        store_cold_dt, store_warm_dt, store_speedup, warm_tally.hits
    );
    let _ = std::fs::remove_dir_all(&store_dir);

    let timestamp = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\n  \"schema\": \"la-imr-bench/1\",\n  \"unix_time\": {timestamp},\n  \"mode\": \"{mode}\",\n  \"workers\": {workers},\n  \"cell_duration_s\": {duration},\n  \"cold_single_pass\": {{\n    \"events\": {events},\n    \"wall_s\": {cold_dt:.4},\n    \"events_per_sec\": {eps:.0}\n  }},\n  \"sweep_cold\": {{\n    \"cells\": {n_cells},\n    \"wall_s\": {sweep_cold_dt:.4},\n    \"cells_per_sec\": {cps:.3}\n  }},\n  \"sweep_repeated\": {{\n    \"cells\": {n_rep},\n    \"wall_s_no_cache\": {rep_cold_dt:.4},\n    \"wall_s_memoized\": {rep_memo_dt:.4},\n    \"memo_speedup\": {memo_speedup:.2}\n  }},\n  \"store_sweep\": {{\n    \"cells\": {n_cells},\n    \"wall_s_cold\": {store_cold_dt:.4},\n    \"wall_s_warm\": {store_warm_dt:.4},\n    \"warm_speedup\": {store_speedup:.2},\n    \"warm_hit_rate\": {warm_hit_rate:.3}\n  }},\n  \"million_robot\": {{\n    \"scenario\": \"{mr_name}\",\n    \"requests\": {mr_requests},\n    \"des\": {{\n      \"wall_s\": {mr_des_dt:.4},\n      \"events\": {mr_des_events},\n      \"requests_per_sec\": {mr_des_rps:.0}\n    }},\n    \"hybrid\": {{\n      \"wall_s\": {mr_hyb_dt:.4},\n      \"events\": {mr_hyb_events},\n      \"fluid_batched\": {mr_fluid},\n      \"requests_per_sec\": {mr_hyb_rps:.0}\n    }},\n    \"hybrid_speedup\": {mr_speedup:.2},\n    \"peak_rss_mb\": {mr_rss}\n  }}\n}}\n",
        mode = if smoke { "smoke" } else { "full" },
        workers = runner_threads,
        events = r.events,
        eps = events_per_sec,
        n_cells = cells.len(),
        cps = cold_cells_per_sec,
        n_rep = repeated.len(),
        mr_name = mr_scenario.name,
        mr_requests = mr_des.generated,
        mr_des_events = mr_des.events,
        mr_hyb_events = mr_hyb.events,
        mr_fluid = mr_hyb.fluid_batched,
        mr_rss = peak_rss_mb.map_or_else(|| "null".to_string(), |mb| format!("{mb:.1}")),
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    print!("{json}");
}
