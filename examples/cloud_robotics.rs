//! End-to-end driver (DESIGN.md's "end-to-end validation"): a CloudGripper
//! -style robot fleet sends synthetic camera frames through the LA-IMR
//! router into REAL compiled detection models on the PJRT CPU client, in
//! closed loop, reporting latency/throughput percentiles.
//!
//! This is the serving-paper analogue of "load a small real model and
//! serve batched requests": all three layers compose — Pallas kernel →
//! JAX graph → HLO artifact → rust runtime → Algorithm-1 routing.
//!
//! Run: `make artifacts && cargo run --release --example cloud_robotics`

use la_imr::config::{Config, QualityClass};
use la_imr::coordinator::state::ReplicaView;
use la_imr::coordinator::{ControlState, Router};
use la_imr::runtime::{postprocess, Runtime};
use la_imr::telemetry::Summary;
use la_imr::workload::RobotFleet;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    println!("PJRT platform={} models={:?}", rt.platform(), rt.model_names());

    // Five robots (the paper's §V-A.1 setup): 3 on the balanced lane,
    // 2 latency-critical on the low-latency lane.
    let mut fleet = RobotFleet::uniform(5, 2.0, QualityClass::Balanced);
    fleet.robots[3].quality = QualityClass::LowLatency;
    fleet.robots[4].quality = QualityClass::LowLatency;

    let mut router = Router::new(&cfg);
    let mut state = ControlState::new();
    // Warm single-replica pools everywhere (view only; execution is local).
    for m in 0..cfg.models.len() {
        for i in 0..cfg.instances.len() {
            state.update(
                la_imr::cluster::DeploymentKey { model: m, instance: i },
                ReplicaView {
                    active: 1,
                    ready: 1,
                    desired: 1,
                    rho: 0.3,
                    queue_depth: 0,
                },
            );
        }
    }

    let frames_per_robot = 40u64;
    let t0 = Instant::now();
    let mut per_lane: std::collections::HashMap<&'static str, Vec<f64>> = Default::default();
    let mut detections = 0usize;
    let mut offloaded = 0usize;
    let mut served = 0usize;

    // Closed loop: robots round-robin; each waits for its detection
    // before the next frame (the CloudGripper interaction pattern).
    for frame in 0..frames_per_robot {
        for robot in &fleet.robots {
            let now = t0.elapsed().as_secs_f64();
            let (model_id, _) = cfg.model_for_quality(robot.quality).unwrap();
            let decision = router.route(model_id, now, &state);
            let art = cfg.models[decision.target.model]
                .artifact
                .as_deref()
                .or(cfg.models[model_id].artifact.as_deref())
                .unwrap();
            let compiled = rt.model(art).unwrap();
            let img = fleet.frame(robot.id, frame, compiled.entry.input_shape[1]);

            let t_req = Instant::now();
            let out = compiled.infer(&img)?;
            let dets = postprocess(&out, rt.manifest.num_classes, 0.52);
            let lat = t_req.elapsed().as_secs_f64();

            detections += dets.len();
            served += 1;
            offloaded += decision.offloaded as usize;
            let lane = match robot.quality {
                QualityClass::LowLatency => "low-latency",
                QualityClass::Balanced => "balanced",
                QualityClass::Precise => "precise",
            };
            per_lane.entry(lane).or_default().push(lat);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\nserved {served} frames in {wall:.2} s → throughput {:.1} req/s, {detections} detections, {:.1}% offloaded",
        served as f64 / wall,
        100.0 * offloaded as f64 / served as f64
    );
    println!("\nper-lane latency (real PJRT inference):");
    let mut lanes: Vec<_> = per_lane.iter().collect();
    lanes.sort_by_key(|(k, _)| *k);
    for (lane, xs) in lanes {
        let s = Summary::from(xs);
        println!(
            "  {lane:<12} n={:<4} mean {:>6.2} ms  P50 {:>6.2}  P95 {:>6.2}  P99 {:>6.2} ms",
            s.count,
            s.mean * 1e3,
            s.p50 * 1e3,
            s.p95 * 1e3,
            s.p99 * 1e3
        );
    }
    println!("\n(Record of this run lives in EXPERIMENTS.md §End-to-end.)");
    Ok(())
}
