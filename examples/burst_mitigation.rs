//! Burst mitigation head-to-head: LA-IMR vs the reactive baseline vs the
//! SafeTail-style hedged comparator on the same bounded-Pareto burst
//! trace (paper §V-B/C in miniature), printing the latency distribution,
//! scaling activity, and offload share. All three cells run concurrently
//! through the sharded runner.
//!
//! Run: `cargo run --release --example burst_mitigation [--lambda 4]`

use la_imr::config::{Config, ScenarioConfig};
use la_imr::sim::{Cell, Policy, Runner};
use la_imr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let lambda = args.get_f64("lambda", 4.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let cfg = Config::default();

    let scenario = ScenarioConfig::bursty(lambda, seed)
        .with_duration(300.0, 30.0)
        .with_replicas(2);
    println!(
        "bounded-Pareto bursts, mean λ={lambda} req/s, 300 s, seed {seed} (identical trace for all policies)\n"
    );

    let policies = [Policy::LaImr, Policy::Baseline, Policy::Hedged];
    let cells: Vec<Cell> = policies
        .iter()
        .map(|&p| Cell::new(scenario.clone(), p))
        .collect();
    let results = Runner::new().run(&cfg, &cells);

    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>9} {:>7} {:>7} {:>9}",
        "policy", "mean[s]", "P50[s]", "P95[s]", "P99[s]", "max[s]", "out", "in", "offload%"
    );
    let mut p99 = Vec::new();
    for r in &results {
        let s = r.summary();
        println!(
            "{:<10} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>9.3} {:>7} {:>7} {:>9.1}",
            r.policy_name,
            s.mean,
            s.p50,
            s.p95,
            s.p99,
            s.max,
            r.scale_outs,
            r.scale_ins,
            100.0 * r.offload_share()
        );
        p99.push(s.p99);
    }
    println!(
        "\nP99 reduction vs baseline: LA-IMR {:.1}%, hedged {:.1}% (paper reports up to 20.7% for LA-IMR on its testbed)",
        100.0 * (1.0 - p99[0] / p99[1]),
        100.0 * (1.0 - p99[2] / p99[1])
    );
    Ok(())
}
