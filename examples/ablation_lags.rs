//! Ablation: where does LA-IMR's advantage come from? (DESIGN.md §6)
//!
//! Sweeps the two actuation lags the paper identifies — Prometheus scrape
//! staleness and pod startup time — and reports the P99 gap between
//! LA-IMR and the reactive baseline at λ=4 bursty. If the paper's story
//! is right, shrinking the *scrape* lag helps the baseline (its signal
//! gets fresher) while shrinking *pod startup* helps both.
//!
//! Run: `cargo run --release --example ablation_lags`

use la_imr::config::{Config, ScenarioConfig};
use la_imr::sim::{Cell, Policy, Runner};

/// Mean P99 over 3 seeds, sharded across the runner.
fn mean3(cfg: &Config, policy: Policy) -> f64 {
    let cells: Vec<Cell> = [101u64, 102, 103]
        .iter()
        .map(|&seed| {
            Cell::new(
                ScenarioConfig::bursty(4.0, seed)
                    .with_duration(300.0, 30.0)
                    .with_replicas(2),
                policy,
            )
        })
        .collect();
    let results = Runner::new().run(cfg, &cells);
    results.iter().map(|r| r.summary().p99).sum::<f64>() / cells.len() as f64
}

fn main() {
    println!("λ=4 bursty, P99 [s] averaged over 3 seeds\n");

    println!("-- scrape-interval sweep (baseline's signal freshness) --");
    println!("{:>10} {:>12} {:>12} {:>8}", "scrape[s]", "LA-IMR", "baseline", "gap");
    for scrape in [5.0, 15.0, 30.0, 60.0] {
        let mut cfg = Config::default();
        cfg.cluster.scrape_interval = scrape;
        let (la, bl) = (mean3(&cfg, Policy::LaImr), mean3(&cfg, Policy::Baseline));
        println!(
            "{scrape:>10} {la:>12.2} {bl:>12.2} {:>7.1}%",
            100.0 * (1.0 - la / bl)
        );
    }

    println!("\n-- pod-startup sweep (actuation speed for both) --");
    println!("{:>10} {:>12} {:>12} {:>8}", "startup[s]", "LA-IMR", "baseline", "gap");
    for startup in [0.5, 1.8, 5.0, 15.0] {
        let mut cfg = Config::default();
        cfg.cluster.pod_startup = startup;
        let (la, bl) = (mean3(&cfg, Policy::LaImr), mean3(&cfg, Policy::Baseline));
        println!(
            "{startup:>10} {la:>12.2} {bl:>12.2} {:>7.1}%",
            100.0 * (1.0 - la / bl)
        );
    }

    println!("\n-- EWMA α sweep (LA-IMR's smoothing; paper uses 0.8) --");
    println!("{:>10} {:>12}", "α", "LA-IMR P99");
    for alpha in [0.0, 0.5, 0.8, 0.95] {
        let mut cfg = Config::default();
        cfg.slo.ewma_alpha = alpha;
        println!("{alpha:>10} {:>12.2}", mean3(&cfg, Policy::LaImr));
    }
}
