"""L2: the two quality-tier detection models served by LA-IMR.

The paper's quality lanes are backed by EfficientDet-Lite0 (edge,
low-latency) and YOLOv5m (balanced). We build two mini-detectors with the
same *two-tier structure* and a compute-cost ratio mirroring Table II's
R_m = 0.10 vs 1.00 CPU-s (see DESIGN.md §3 Substitutions): small conv
backbones + a 1x1 detection head, all convs expressed as im2col + the L1
Pallas matmul kernel so every FLOP flows through the kernel.

Weights are generated deterministically from a per-model seed and closed
over as HLO constants, so the AOT artifact is fully self-contained: the
rust runtime feeds one image tensor and receives one detection tensor.

Output: (num_cells, 4 + NUM_CLASSES) f32, sigmoid-activated —
[cx, cy, w, h, p(class_0..3)] per grid cell. Post-processing (score
threshold, argmax class) happens in rust (`runtime::postprocess`).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import matmul, matmul_bias_silu
from .kernels.ref import im2col_ref

# CloudGripper-inspired object classes: cube, strip, gripper, background.
NUM_CLASSES = 4


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv + bias + SiLU block (VALID padding)."""

    kh: int
    kw: int
    stride: int
    c_in: int
    c_out: int


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of a mini-detector."""

    name: str
    seed: int
    input_hw: int  # square input, NHWC with N=1, C=3
    blocks: tuple[ConvSpec, ...]

    @property
    def input_shape(self) -> tuple[int, int, int, int]:
        return (1, self.input_hw, self.input_hw, 3)

    def out_hw(self) -> int:
        """Spatial size after all backbone blocks (VALID padding)."""
        h = self.input_hw
        for b in self.blocks:
            h = (h - b.kh) // b.stride + 1
        return h

    @property
    def num_cells(self) -> int:
        return self.out_hw() ** 2

    @property
    def output_shape(self) -> tuple[int, int]:
        return (self.num_cells, 4 + NUM_CLASSES)

    def flops(self) -> int:
        """Approximate multiply-add FLOPs for one inference."""
        total = 0
        h = self.input_hw
        for b in self.blocks:
            oh = (h - b.kh) // b.stride + 1
            total += 2 * oh * oh * b.c_out * b.kh * b.kw * b.c_in
            h = oh
        # 1x1 detection head
        total += 2 * h * h * (4 + NUM_CLASSES) * self.blocks[-1].c_out
        return total


# Tier-1, edge-optimised ("EfficientDet-Lite0 class"): ~1.3 MFLOP.
EFFDET_LITE = ModelSpec(
    name="effdet_lite",
    seed=11,
    input_hw=64,
    blocks=(
        ConvSpec(3, 3, 2, 3, 8),
        ConvSpec(3, 3, 2, 8, 16),
        ConvSpec(3, 3, 2, 16, 24),
    ),
)

# Tier-2, balanced ("YOLOv5m class"): ~20x the FLOPs of the edge model,
# mirroring Table II's order-of-magnitude R_m gap.
YOLOV5M = ModelSpec(
    name="yolov5m",
    seed=22,
    input_hw=96,
    blocks=(
        ConvSpec(3, 3, 2, 3, 16),
        ConvSpec(3, 3, 2, 16, 32),
        ConvSpec(3, 3, 1, 32, 48),
        ConvSpec(3, 3, 2, 48, 64),
    ),
)

MODELS: dict[str, ModelSpec] = {m.name: m for m in (EFFDET_LITE, YOLOV5M)}


def init_params(spec: ModelSpec) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Deterministic He-style init; weights become HLO constants at AOT."""
    params = []
    key = jax.random.PRNGKey(spec.seed)
    for blk in spec.blocks:
        key, kw_, kb_ = jax.random.split(key, 3)
        fan_in = blk.kh * blk.kw * blk.c_in
        w = jax.random.normal(
            kw_, (blk.kh, blk.kw, blk.c_in, blk.c_out), jnp.float32
        ) * jnp.sqrt(2.0 / fan_in)
        b = jax.random.normal(kb_, (blk.c_out,), jnp.float32) * 0.01
        params.append((w, b))
    # 1x1 detection head (no activation before sigmoid).
    key, kw_, kb_ = jax.random.split(key, 3)
    c_in = spec.blocks[-1].c_out
    w = jax.random.normal(
        kw_, (1, 1, c_in, 4 + NUM_CLASSES), jnp.float32
    ) * jnp.sqrt(1.0 / c_in)
    b = jax.random.normal(kb_, (4 + NUM_CLASSES,), jnp.float32) * 0.01
    params.append((w, b))
    return params


def conv_block(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int,
    *,
    fuse_silu: bool = True,
) -> jnp.ndarray:
    """Conv (VALID) + bias [+ SiLU] as im2col + the L1 Pallas matmul.

    x: (1, H, W, C_in), w: (KH, KW, C_in, C_out) HWIO -> (1, OH, OW, C_out).
    """
    _, h, _, _ = x.shape
    kh, kw_, c_in, c_out = w.shape
    oh = (h - kh) // stride + 1
    cols = im2col_ref(x, kh, kw_, stride)  # (OH*OW, KH*KW*C_in)
    wmat = w.reshape(kh * kw_ * c_in, c_out)
    if fuse_silu:
        out = matmul_bias_silu(cols, wmat, b)
    else:
        out = matmul(cols, wmat, b, fuse="none")
    return out.reshape(1, oh, oh, c_out)


def forward(spec: ModelSpec, params, image: jnp.ndarray) -> jnp.ndarray:
    """Full detector forward pass: image (1,H,W,3) -> (cells, 4+C) sigmoid."""
    x = image
    for blk, (w, b) in zip(spec.blocks, params[:-1]):
        x = conv_block(x, w, b, blk.stride, fuse_silu=True)
    w, b = params[-1]
    x = conv_block(x, w, b, 1, fuse_silu=False)  # head: linear 1x1
    x = x.reshape(spec.num_cells, 4 + NUM_CLASSES)
    return jax.nn.sigmoid(x)


def build_infer_fn(spec: ModelSpec):
    """Close params over as constants; returns fn(image) -> (detections,).

    The 1-tuple return matches the return_tuple=True lowering contract the
    rust loader unwraps with to_tuple1() (see /opt/xla-example/README.md).
    """
    params = init_params(spec)

    def infer(image: jnp.ndarray):
        return (forward(spec, params, image),)

    return infer


def reference_forward(spec: ModelSpec, image: jnp.ndarray) -> jnp.ndarray:
    """Same network through the pure-jnp conv oracle (no Pallas) — used by
    pytest to validate the whole L2 graph against lax convolutions."""
    from .kernels.ref import conv2d_silu_ref

    params = init_params(spec)
    x = image
    for blk, (w, b) in zip(spec.blocks, params[:-1]):
        x = conv2d_silu_ref(x, w, b, blk.stride)
    w, b = params[-1]
    import jax.lax as lax

    z = lax.conv_general_dilated(
        x, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + b[None, None, None, :]
    return jax.nn.sigmoid(z.reshape(spec.num_cells, 4 + NUM_CLASSES))
