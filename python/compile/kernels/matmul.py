"""L1 Pallas kernel: tiled matmul with fused bias + SiLU epilogue.

This is the compute hot-spot of both mini-detectors (conv is expressed as
im2col + matmul in model.py, so ~all FLOPs flow through here).

TPU-idiomatic structure (see DESIGN.md §Hardware-Adaptation):
  * the grid tiles (M, N, K) into (bm, bn, bk) blocks sized for the MXU
    (multiples of 128 where the problem allows) and for VMEM residency —
    three live f32 tiles of 128x128 are ~192 KiB, far under the ~16 MiB
    VMEM budget, leaving room for double-buffered prefetch;
  * the K-loop is the innermost grid dimension so each (i, j) output tile
    accumulates in-place in VMEM across K steps (revolving accumulator);
  * the bias + SiLU epilogue is fused into the final K step, avoiding an
    HBM round-trip for the activation.

MUST run with interpret=True on CPU-PJRT: real TPU lowering emits a Mosaic
custom-call the CPU plugin cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, nsteps_k: int, fuse: str):
    """Grid = (M/bm, N/bn, K/bk); accumulate over the trailing K axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    if fuse == "silu":
        @pl.when(k == nsteps_k - 1)
        def _epilogue():
            z = o_ref[...]
            o_ref[...] = z * (1.0 / (1.0 + jnp.exp(-z)))


def _bias_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps_k: int, fuse: str):
    """Same as _matmul_kernel but with a bias row added in the epilogue."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps_k - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...]
        if fuse == "silu":
            z = z * (1.0 / (1.0 + jnp.exp(-z)))
        o_ref[...] = z


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps grid exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "fuse", "interpret")
)
def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    fuse: str = "none",
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled Pallas matmul: (M, K) @ (K, N) [+ b] [SiLU] -> (M, N).

    Block sizes are clamped to divisors of the problem dims so the grid is
    exact (no masking needed); 128 targets the MXU systolic array width.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert fuse in ("none", "silu")

    bm = _block(m, bm)
    bn = _block(n, bn)
    bk = _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    nsteps_k = grid[2]

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, s: (i, s))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, s: (s, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))

    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)

    if b is None:
        kern = functools.partial(_matmul_kernel, nsteps_k=nsteps_k, fuse=fuse)
        out = pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(x.astype(jnp.float32), w.astype(jnp.float32))
    else:
        b_spec = pl.BlockSpec((1, bn), lambda i, j, s: (0, j))
        kern = functools.partial(_bias_kernel, nsteps_k=nsteps_k, fuse=fuse)
        out = pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[x_spec, w_spec, b_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            b.astype(jnp.float32).reshape(1, -1),
        )
    return out.astype(x.dtype)


def matmul_bias_silu(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, **kw
) -> jnp.ndarray:
    """Convenience wrapper matching ref.matmul_bias_silu_ref's signature."""
    return matmul(x, w, b, fuse="silu", **kw)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated live VMEM per grid step: x, w, o tiles (+bias row).

    Used by DESIGN.md §Perf to justify the BlockSpec choice and by
    python/tests to assert the default tiling stays under budget.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn + bn)
