"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has an oracle here with an identical
signature. pytest (python/tests/) asserts allclose between kernel and
oracle across a hypothesis-driven sweep of shapes and dtypes — this is
the core correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp


def _silu(z: jnp.ndarray) -> jnp.ndarray:
    return z * (1.0 / (1.0 + jnp.exp(-z)))


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle: (M, K) @ (K, N) -> (M, N) with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def matmul_bias_silu_ref(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Fused matmul + bias + SiLU oracle.

    SiLU(z) = z * sigmoid(z) — the activation used by both mini-detector
    backbones (YOLOv5 and EfficientDet both use SiLU/Swish variants).
    """
    z = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    z = z + b.astype(jnp.float32)[None, :]
    return _silu(z).astype(x.dtype)


def im2col_ref(x: jnp.ndarray, kh: int, kw: int, stride: int) -> jnp.ndarray:
    """im2col oracle: NHWC image -> (N*OH*OW, KH*KW*C) patch matrix.

    'VALID' padding. This is the data-layout half of conv-as-matmul; the
    compute half goes through matmul_bias_silu_ref / the Pallas kernel.
    Patch column order is (kh, kw, c) to match model.py's weight reshape.
    """
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            cols.append(patch.reshape(n * oh * ow, c))
    return jnp.concatenate(cols, axis=1)


def conv2d_silu_ref(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, stride: int = 1
) -> jnp.ndarray:
    """Reference conv2d (VALID padding) + bias + SiLU via lax, NHWC / HWIO."""
    import jax.lax as lax

    z = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    z = z + b.astype(jnp.float32)[None, None, None, :]
    return _silu(z).astype(x.dtype)
