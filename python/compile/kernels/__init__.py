"""L1: Pallas kernels for the inference hot-spot (conv-as-matmul).

Public surface:
  matmul            — tiled Pallas matmul, optional fused bias + SiLU
  matmul_bias_silu  — fused epilogue convenience wrapper
  vmem_bytes        — VMEM-footprint estimator for a BlockSpec choice
  ref               — pure-jnp oracles (correctness ground truth)
"""

from . import ref  # noqa: F401
from .matmul import matmul, matmul_bias_silu, vmem_bytes  # noqa: F401
