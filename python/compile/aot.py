"""AOT lowering: JAX models -> HLO text artifacts for the rust runtime.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also emits artifacts/manifest.json (shapes + FLOPs, read by rust config)
and an HLO op-count report used as the L2 fusion sanity check (§Perf).

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import collections
import json
import os
import re

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS, NUM_CLASSES, build_infer_fn


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (0.5.1-safe path).

    print_large_constants=True is ESSENTIAL: the default printer elides
    big literals as `constant({...})`, and the rust-side text parser then
    reads garbage — the model's closed-over weights would silently vanish
    and the executable would ignore its input.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def op_histogram(hlo_text: str) -> dict[str, int]:
    """Count HLO ops per opcode — the L2 graph-shape report."""
    hist: collections.Counter[str] = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\],{}\s]+?\s(\w+)\(", line)
        if m:
            hist[m.group(1)] += 1
    return dict(hist)


def lower_model(name: str):
    spec = MODELS[name]
    fn = build_infer_fn(spec)
    image = jax.ShapeDtypeStruct(spec.input_shape, jnp.float32)
    return spec, jax.jit(fn).lower(image)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    args = ap.parse_args()

    # `--out` may be the artifacts dir or (legacy Makefile) a single .hlo.txt
    # path inside it; normalise to the directory.
    out_dir = args.out
    if out_dir.endswith(".txt"):
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest: dict[str, dict] = {"num_classes": NUM_CLASSES, "models": {}}
    for name in args.models:
        spec, lowered = lower_model(name)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        hist = op_histogram(text)

        # Golden cross-check: a deterministic ramp input and the jax-side
        # output. The rust integration tests re-run the compiled artifact
        # on the same input and assert allclose — this is the contract
        # that catches silent artifact corruption (e.g. elided constants).
        n_in = 1
        for d in spec.input_shape:
            n_in *= d
        ramp = (jnp.arange(n_in, dtype=jnp.float32) % 97.0) / 97.0
        golden_in = ramp.reshape(spec.input_shape)
        golden_out = jax.jit(build_infer_fn(spec))(golden_in)[0]
        golden = [float(x) for x in jnp.asarray(golden_out).ravel()[:32]]

        manifest["models"][name] = {
            "hlo": f"{name}.hlo.txt",
            "input_shape": list(spec.input_shape),
            "output_shape": list(spec.output_shape),
            "flops": spec.flops(),
            "hlo_ops": hist,
            "golden_prefix": golden,
        }
        print(
            f"{name}: wrote {len(text)} chars -> {path} "
            f"({spec.flops()/1e6:.2f} MFLOP, {sum(hist.values())} HLO ops)"
        )

    # Legacy Makefile stamp target (artifacts/model.hlo.txt) — keep it valid
    # by symlinking the first model so `make -q artifacts` stays accurate.
    stamp = os.path.join(out_dir, "model.hlo.txt")
    first = f"{args.models[0]}.hlo.txt"
    if os.path.islink(stamp) or os.path.exists(stamp):
        os.remove(stamp)
    os.symlink(first, stamp)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest -> {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
