"""L1 kernel correctness: Pallas matmul vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes; assert_allclose against ref.py per the
repo's test contract. All pallas_calls run interpret=True (CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_bias_silu, ref, vmem_bytes

DIMS = st.sampled_from([1, 2, 3, 4, 7, 8, 16, 27, 32, 47, 49, 64, 100, 128])


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=1e-5, atol=1e-5)
    )


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS)
def test_matmul_matches_ref_shapes(m, k, n):
    x = _rand(0, (m, k), jnp.float32)
    w = _rand(1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)),
        np.asarray(ref.matmul_ref(x, w)),
        rtol=1e-5,
        atol=1e-5,
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS)
def test_matmul_bias_silu_matches_ref_shapes(m, k, n):
    x = _rand(2, (m, k), jnp.float32)
    w = _rand(3, (k, n), jnp.float32)
    b = _rand(4, (n,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(matmul_bias_silu(x, w, b)),
        np.asarray(ref.matmul_bias_silu_ref(x, w, b)),
        rtol=1e-5,
        atol=1e-5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(5, (32, 16), dtype)
    w = _rand(6, (16, 8), dtype)
    got = matmul(x, w)
    assert got.dtype == dtype
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_dtypes(dtype):
    x = _rand(7, (16, 32), dtype)
    w = _rand(8, (32, 8), dtype)
    b = _rand(9, (8,), dtype)
    got = matmul_bias_silu(x, w, b)
    assert got.dtype == dtype
    want = ref.matmul_bias_silu_ref(x, w, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_block_shapes(bm, bn, bk):
    """Tiling must not change results (accumulator across K steps)."""
    x = _rand(10, (64, 96), jnp.float32)
    w = _rand(11, (96, 32), jnp.float32)
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-5, atol=1e-5
    )


def test_k_accumulation_multi_step():
    """K larger than bk exercises the revolving-accumulator path."""
    x = _rand(12, (16, 256), jnp.float32)
    w = _rand(13, (256, 16), jnp.float32)
    got = matmul(x, w, bk=32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-5, atol=1e-5
    )


def test_non_divisible_dims_clamped():
    """Odd/prime dims fall back to divisor block sizes, still correct."""
    x = _rand(14, (47, 27), jnp.float32)  # 47x47 grid cells, 3x3x3 patches
    w = _rand(15, (27, 16), jnp.float32)
    got = matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)), rtol=1e-5, atol=1e-5
    )


def test_silu_epilogue_only_on_last_k_step():
    """With multiple K steps the epilogue must apply exactly once."""
    x = _rand(16, (8, 64), jnp.float32)
    w = _rand(17, (64, 8), jnp.float32)
    b = _rand(18, (8,), jnp.float32)
    got = matmul_bias_silu(x, w, b, bk=16)  # 4 K-steps
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ref.matmul_bias_silu_ref(x, w, b)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_im2col_ref_patch_order():
    """im2col column order must be (kh, kw, c) to match weight reshape."""
    x = jnp.arange(2 * 3 * 3 * 2, dtype=jnp.float32).reshape(2, 3, 3, 2)
    cols = ref.im2col_ref(x, 2, 2, 1)
    assert cols.shape == (2 * 2 * 2, 2 * 2 * 2)
    # First output row = patch at (0,0) of image 0, order (kh,kw,c).
    want = jnp.concatenate([x[0, 0, 0], x[0, 0, 1], x[0, 1, 0], x[0, 1, 1]])
    np.testing.assert_allclose(np.asarray(cols[0]), np.asarray(want))


def test_im2col_stride2():
    x = jax.random.normal(jax.random.PRNGKey(20), (1, 8, 8, 3), jnp.float32)
    cols = ref.im2col_ref(x, 3, 3, 2)
    assert cols.shape == (3 * 3, 27)


def test_vmem_budget_default_tiles():
    """Default 128^3 f32 tiling must stay far under the 16 MiB VMEM budget
    (DESIGN.md §Perf: <= 512 KiB live per grid step)."""
    assert vmem_bytes(128, 128, 128) <= 512 * 1024


def test_matmul_rejects_mismatched_inner_dims():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 4))
    with pytest.raises(AssertionError):
        matmul(x, w)
