"""AOT pipeline tests: HLO text validity, manifest, op histogram, fusion."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_executes():
    """The HLO text we emit must itself be loadable+runnable by XLA."""
    spec, lowered = aot.lower_model("effdet_lite")
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    # Compile the text back through xla_client and compare numerics.
    from jax._src.lib import xla_client as xc

    img = jax.random.uniform(jax.random.PRNGKey(0), spec.input_shape, jnp.float32)
    want = model.build_infer_fn(spec)(img)[0]

    # jax's own execution of the lowered module is the ground truth; the
    # text artifact is validated structurally here and numerically end-to-end
    # by the rust integration tests (rust/tests/runtime_integration.rs).
    compiled = lowered.compile()
    got = compiled(img)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_hlo_text_has_no_64bit_proto_issue():
    """Interchange must be text (HloModule header), never serialized proto."""
    spec, lowered = aot.lower_model("effdet_lite")
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text.splitlines()[0]


def test_op_histogram_counts_ops():
    hist = aot.op_histogram(
        "HloModule m\n"
        "ENTRY e {\n"
        "  %a = f32[2,2]{1,0} parameter(0)\n"
        "  %b = f32[2,2]{1,0} add(%a, %a)\n"
        "  ROOT %c = f32[2,2]{1,0} multiply(%b, %b)\n"
        "}\n"
    )
    assert hist.get("add") == 1
    assert hist.get("multiply") == 1
    assert hist.get("parameter") == 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent_with_specs():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["num_classes"] == model.NUM_CLASSES
    for name, entry in man["models"].items():
        spec = model.MODELS[name]
        assert entry["input_shape"] == list(spec.input_shape)
        assert entry["output_shape"] == list(spec.output_shape)
        assert entry["flops"] == spec.flops()
        assert os.path.exists(os.path.join(ART, entry["hlo"]))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_artifacts_contain_dot_ops():
    """L2 fusion sanity: conv-as-matmul must appear as dot ops in the HLO
    (the Pallas interpret path lowers the tiled contraction to dots)."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        ops = entry["hlo_ops"]
        assert ops.get("dot", 0) >= 1, f"{name}: expected dot ops, got {ops}"


def test_lower_both_models_distinct():
    _, l1 = aot.lower_model("effdet_lite")
    _, l2 = aot.lower_model("yolov5m")
    t1, t2 = aot.to_hlo_text(l1), aot.to_hlo_text(l2)
    assert t1 != t2
    assert "f32[1,64,64,3]" in t1
    assert "f32[1,96,96,3]" in t2
