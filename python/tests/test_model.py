"""L2 model tests: graph correctness vs lax oracle, shapes, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import MODELS, NUM_CLASSES


@pytest.fixture(scope="module", params=list(MODELS))
def spec(request):
    return MODELS[request.param]


def _image(spec, seed=42):
    return jax.random.uniform(jax.random.PRNGKey(seed), spec.input_shape, jnp.float32)


def test_forward_matches_lax_reference(spec):
    """The whole Pallas-backed graph must match plain lax convolutions."""
    img = _image(spec)
    got = model.build_infer_fn(spec)(img)[0]
    want = model.reference_forward(spec, img)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_output_shape_and_range(spec):
    out = model.build_infer_fn(spec)(_image(spec))[0]
    assert out.shape == spec.output_shape
    assert out.shape[1] == 4 + NUM_CLASSES
    o = np.asarray(out)
    assert (o >= 0).all() and (o <= 1).all(), "sigmoid output must be in [0,1]"


def test_deterministic_weights(spec):
    """Same seed -> identical params (artifact reproducibility)."""
    p1 = model.init_params(spec)
    p2 = model.init_params(spec)
    for (w1, b1), (w2, b2) in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_models_differ():
    """The two quality tiers must be distinct networks."""
    e, y = MODELS["effdet_lite"], MODELS["yolov5m"]
    assert e.input_hw != y.input_hw
    assert y.flops() > 10 * e.flops(), "tier cost gap must mirror Table II"


def test_flops_accounting(spec):
    """flops() must equal the sum over conv blocks computed independently."""
    total, h = 0, spec.input_hw
    for b in spec.blocks:
        oh = (h - b.kh) // b.stride + 1
        total += 2 * oh * oh * b.c_out * b.kh * b.kw * b.c_in
        h = oh
    total += 2 * h * h * (4 + NUM_CLASSES) * spec.blocks[-1].c_out
    assert spec.flops() == total


def test_out_hw_valid_padding(spec):
    h = spec.input_hw
    for b in spec.blocks:
        h = (h - b.kh) // b.stride + 1
    assert spec.out_hw() == h
    assert spec.num_cells == h * h


def test_conv_block_single(spec):
    """One conv block vs the conv oracle in isolation."""
    from compile.kernels.ref import conv2d_silu_ref

    blk = spec.blocks[0]
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 16, 16, blk.c_in), jnp.float32)
    w = jax.random.normal(key, (blk.kh, blk.kw, blk.c_in, blk.c_out), jnp.float32)
    b = jax.random.normal(key, (blk.c_out,), jnp.float32)
    got = model.conv_block(x, w, b, blk.stride)
    want = conv2d_silu_ref(x, w, b, blk.stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
